"""VerticalSession — the single entrypoint for every PyVertical workflow.

The paper's pipeline (Fig. 2) as a facade over the repo's machinery:

    sci, owners = feature_parties(*make_vertical_mnist_parties(2000))
    session = VerticalSession(sci, owners)
    session.resolve(group="modp512")          # DH-PSI + ID alignment
    session.build(CONFIG)                     # MLPSplitNN | SplitModel
    history = session.fit(epochs=10, batch_size=128, eval_frac=0.15)
    engine = session.serve(...)               # split-inference (LM archs)

Party-visibility contract (enforced, see ``tests/test_federation.py``):
owners never see labels, the scientist never receives raw feature arrays.
Every cross-party message the session mediates is appended to
``session.transcript``; during training the only owner->scientist payloads
are PSI responses and cut-layer activations (claim C4), and the only
scientist->owner payloads are blinded PSI sets, the resolved-ID broadcast,
and cut-layer gradients.
"""
from __future__ import annotations

import queue as _queue
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.psi import GROUPS, PSIClient, PSIServer
from repro.core.splitnn import (cut_layer_traffic, make_split_train_step,
                                train_state_init)
from repro.federation import batching, transport
from repro.federation.parties import (DataOwner, DataScientist,
                                      OwnerComputeEndpoint, PrivacyError)
from repro.federation.registry import build_adapter
from repro.optim import apply_updates


class VerticalSession:
    """Orchestrates one scientist + N owners through resolve / build /
    fit / evaluate / serve.  The session itself is the trusted simulation
    runtime; party objects keep their raw data private."""

    def __init__(self, scientist: DataScientist,
                 owners: Union[Sequence[DataOwner], Dict[str, DataOwner]],
                 *, seed: int = 0):
        self.scientist = scientist
        self.owners: List[DataOwner] = (list(owners.values())
                                        if isinstance(owners, dict)
                                        else list(owners))
        if len({o.name for o in self.owners}) != len(self.owners):
            raise ValueError("owner names must be unique")
        if not self.owners:
            raise ValueError("need at least one data owner")
        self.seed = seed
        self.transcript: List[dict] = []
        self.resolve_stats: Optional[dict] = None
        self.transport_stats: Optional[dict] = None
        self.adapter = None
        self.params = None
        self.history: Optional[dict] = None
        self._resolved = False
        self._eval_idx = np.arange(0)
        self._train_idx: Optional[np.ndarray] = None
        self._eval_fn = None

    # ------------------------------------------------------------- plumbing

    def _log(self, frm: str, to: str, kind: str, **payload):
        self.transcript.append({"from": frm, "to": to, "kind": kind,
                                **payload})

    def _owner_arrays(self) -> List[np.ndarray]:
        """Owner-side accessor: aligned per-owner feature matrices.  These
        arrays feed the jitted joint step (the simulation of owner-local
        head computation); they are never attached to the scientist."""
        return [o._features for o in self.owners]

    def _require(self, *, resolved=False, built=False, labels=False):
        if resolved and not self._resolved:
            raise RuntimeError("call session.resolve() before training — "
                               "parties are not ID-aligned yet")
        if built and self.adapter is None:
            raise RuntimeError("call session.build(config) first")
        if labels and not self.scientist.has_labels:
            raise PrivacyError("the scientist holds no labels; this "
                               "session supports inference only")

    # ------------------------------------------------------------ 1. resolve

    def resolve(self, *, group: str = "modp2048",
                fp_rate: float = 1e-9) -> dict:
        """The paper's §3.1 protocol: the scientist runs DH-PSI pairwise
        with each owner (scientist = client, so only the scientist learns
        each intersection), intersects globally, broadcasts the shared IDs,
        and every party filter-and-sorts.  Returns the stats dict."""
        nb = GROUPS[group][2]
        stats: dict = {"rounds": [], "global_intersection": 0}
        global_ids = set(self.scientist.ids)
        for owner in self.owners:
            client = PSIClient(self.scientist.ids, group)
            server = PSIServer(owner.ids, fp_rate, group)
            blinded = client.blind()
            double, bf = server.respond(blinded)
            inter = client.intersect(double, bf)
            global_ids &= set(inter)
            up, down = nb * len(blinded), nb * len(double) + bf.nbytes()
            self._log("scientist", owner.name, "psi_blinded", bytes=up)
            self._log(owner.name, "scientist", "psi_response", bytes=down,
                      width=None)
            stats["rounds"].append({
                "owner": owner.name, "intersection_size": len(inter),
                "client_upload_bytes": up, "server_response_bytes": down,
                "bloom_bytes": bf.nbytes()})
        stats["global_intersection"] = len(global_ids)
        self.scientist._align(global_ids)
        for owner in self.owners:
            owner._align(global_ids)
            self._log("scientist", owner.name, "resolved_ids",
                      count=len(global_ids))
            # invariant SplitNN training relies on: identical ID order
            assert owner.ids == self.scientist.ids, \
                f"misaligned owner {owner.name}"
        self._resolved = True
        self.resolve_stats = stats
        return stats

    # -------------------------------------------------------------- 2. build

    def build(self, config, *, seed: Optional[int] = None
              ) -> "VerticalSession":
        """Instantiate the split model for ``config`` via the registry
        (``MLPSplitConfig`` -> MLPSplitNN, ``ArchConfig`` -> SplitModel)
        and initialize per-party parameters."""
        self.adapter = build_adapter(config)
        key = jax.random.PRNGKey(self.seed if seed is None else seed)
        self.params = self.adapter.init(key)
        self._eval_fn = jax.jit(
            lambda p, b: self.adapter.loss_fn(p, b)[1])
        return self

    # ---------------------------------------------------------------- 3. fit

    def fit(self, *, epochs: Optional[int] = None,
            steps: Optional[int] = None, batch_size: int = 128,
            eval_frac: float = 0.0, owner_lr: Optional[float] = None,
            scientist_lr: Optional[float] = None,
            log_every: Optional[int] = None, ckpt_dir: Optional[str] = None,
            ckpt_every: int = 0, shuffle_seed: Optional[int] = None,
            verbose: bool = True, mode: str = "joint",
            schedule: str = "pipelined",
            compression: Optional[str] = None, backend: str = "queue",
            latency_s: float = 0.0,
            bandwidth_bps: Optional[float] = None) -> dict:
        """The SplitNN training loop.

        Exactly one of ``epochs`` (feature workloads) / ``steps`` (LM
        workloads) must be given.  ``eval_frac`` holds out the last
        fraction of aligned rows; per-epoch (or final) eval metrics land
        in ``history["eval"]``.  ``ckpt_dir``+``ckpt_every`` write
        per-party checkpoints through ``repro.checkpoint.save_split``.
        Returns ``{"train": [...], "eval": [...], "final": {...}}``.

        ``mode="joint"`` (default) runs the single jitted autodiff
        program — the gradient-equivalence oracle.  ``mode="split"``
        runs *true split execution*: each owner's head segment executes
        on its own thread behind a ``federation.transport`` channel, and
        the only cross-party tensors are cut activations / cut gradients
        — measured wire bytes, not estimates (``self.transport_stats``).
        Split-mode knobs: ``schedule`` ("pipelined" overlaps owner
        compute for batch t+1 with the scientist's trunk update for
        batch t; "sequential" is the fully synchronous baseline),
        ``compression`` (None | "fp16" | "int8" cut-payload codec),
        ``backend`` ("queue" = serialized simulated network, "direct" =
        in-process reference passing), ``latency_s``/``bandwidth_bps``
        (injected per-message transit time)."""
        self._require(resolved=True, built=True, labels=True)
        if (epochs is None) == (steps is None):
            raise ValueError("pass exactly one of epochs= or steps=")
        if mode not in ("joint", "split"):
            raise ValueError(f"mode must be 'joint' or 'split': {mode!r}")
        if mode == "split":
            return self._fit_split(
                epochs=epochs, steps=steps, batch_size=batch_size,
                eval_frac=eval_frac, owner_lr=owner_lr,
                scientist_lr=scientist_lr, log_every=log_every,
                ckpt_dir=ckpt_dir, ckpt_every=ckpt_every,
                shuffle_seed=shuffle_seed, verbose=verbose,
                schedule=schedule, compression=compression,
                backend=backend, latency_s=latency_s,
                bandwidth_bps=bandwidth_bps)

        n = len(self.scientist.ids)
        n_train = n - int(n * eval_frac)
        if n_train < batch_size:
            raise ValueError(f"{n_train} train rows < batch {batch_size}")
        self._train_idx = np.arange(n_train)
        self._eval_idx = np.arange(n_train, n)

        adapter = self.adapter
        opt = adapter.default_optimizer(owner_lr, scientist_lr)
        state = train_state_init(self.params, opt)
        step_fn = make_split_train_step(adapter.loss_fn, opt, donate=False)

        # the per-step protocol traffic, recorded once (static shapes)
        for owner in self.owners:
            shape = adapter.cut_shape(batch_size, owner.feature_shape)
            self._log(owner.name, "scientist", "cut_activations",
                      shape=shape, width=shape[-1], per_step=True)
            self._log("scientist", owner.name, "cut_gradients",
                      shape=shape, per_step=True)

        owner_arrays = self._owner_arrays()
        labels = self.scientist.labels
        rng = np.random.default_rng(self.seed if shuffle_seed is None
                                    else shuffle_seed)
        history: dict = {"train": [], "eval": []}
        t0 = time.time()
        metrics = {}

        def scalars(m):
            return {k: float(v) for k, v in m.items()}

        stream = self._index_stream(rng, n_train, batch_size, epochs, steps)
        if epochs is not None:
            steps_per_epoch = (n_train - batch_size) // batch_size + 1
            global_step = 0
            for ep in range(epochs):
                for _ in range(steps_per_epoch):
                    batch = adapter.make_batch(
                        owner_arrays, labels, next(stream))
                    self.params, state, metrics = step_fn(
                        self.params, state, batch, global_step)
                    global_step += 1
                rec = {"epoch": ep, **scalars(metrics)}
                history["train"].append(rec)
                if len(self._eval_idx):
                    history["eval"].append(
                        {"epoch": ep, **self.evaluate()})
                if verbose and (ep % (log_every or 1) == 0
                                or ep == epochs - 1):
                    ev = history["eval"][-1] if history["eval"] else {}
                    extra = "".join(f" val_{k}={v:.4f}"
                                    for k, v in ev.items() if k != "epoch")
                    print(f"epoch {ep:3d} " + " ".join(
                        f"{k}={v:.4f}" for k, v in rec.items()
                        if k != "epoch") + extra +
                        f" ({time.time() - t0:.1f}s)")
                if ckpt_dir and ckpt_every and (ep + 1) % ckpt_every == 0:
                    self.checkpoint(ckpt_dir, ep + 1)
        else:
            for i in range(steps):
                batch = adapter.make_batch(owner_arrays, labels,
                                           next(stream))
                self.params, state, metrics = step_fn(
                    self.params, state, batch, i)
                rec = {"step": i, **scalars(metrics)}
                history["train"].append(rec)
                if verbose and log_every and (i % log_every == 0
                                              or i == steps - 1):
                    print(f"step {i:5d} " + " ".join(
                        f"{k}={v:.4f}" for k, v in rec.items()
                        if k != "step") + f" ({time.time() - t0:.1f}s)")
                if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
                    self.checkpoint(ckpt_dir, i + 1)
            if len(self._eval_idx):
                history["eval"].append({"step": steps, **self.evaluate()})

        final = dict(history["train"][-1]) if history["train"] else {}
        if history["eval"]:
            final.update({f"val_{k}": v
                          for k, v in history["eval"][-1].items()
                          if k not in ("epoch", "step")})
        history["final"] = final
        self.history = history
        return history

    def _index_stream(self, rng, n_train, batch_size, epochs, steps):
        """The batch-index stream — ONE generator shared by the joint
        and split training loops, so both consume the shuffle rng
        identically (split-mode gradient equivalence is bit-for-bit
        against the joint path and depends on this).  epochs-mode:
        a fresh permutation per epoch, full batches only; steps-mode:
        reshuffle whenever the remaining tail can't fill a batch."""
        if epochs is not None:
            for _ in range(epochs):
                order = rng.permutation(self._train_idx)
                for s in range(0, n_train - batch_size + 1, batch_size):
                    yield order[s:s + batch_size]
        else:
            order = rng.permutation(self._train_idx)
            cursor = 0
            for _ in range(steps):
                if cursor + batch_size > n_train:
                    order = rng.permutation(self._train_idx)
                    cursor = 0
                yield order[cursor:cursor + batch_size]
                cursor += batch_size

    # ------------------------------------------------- 3b. split execution

    def _recv_from_owner(self, ep, worker, kind, timeout: float = 120.0):
        """Receive ``kind`` from one owner, surfacing a dead worker
        immediately (short poll) instead of after the full timeout."""
        deadline = time.monotonic() + timeout
        while True:
            try:
                return ep.recv_kind(kind, timeout=1.0)
            except _queue.Empty:
                if worker.error is not None:
                    raise RuntimeError(
                        f"owner worker {worker.owner.name!r} failed"
                    ) from worker.error
                if time.monotonic() > deadline:
                    raise RuntimeError(
                        f"timed out waiting for {kind!r} from "
                        f"{worker.owner.name!r}")

    def _sync_split_params(self, workers, eps, trunk_params):
        """Flush every owner's message queue (barrier), then reassemble
        the session-resident param tree from the owners' live segments —
        the trusted-runtime accessor, mirroring ``_owner_arrays``."""
        for ep in eps:
            ep.send("barrier", {}, seq=-1)
        for ep, w in zip(eps, workers):
            self._recv_from_owner(ep, w, "barrier_ack")
        self.params = {
            "heads": self.adapter.stack_head_params(
                [w.params for w in workers]),
            "trunk": trunk_params}

    def _fit_split(self, *, epochs, steps, batch_size, eval_frac, owner_lr,
                   scientist_lr, log_every, ckpt_dir, ckpt_every,
                   shuffle_seed, verbose, schedule, compression, backend,
                   latency_s, bandwidth_bps) -> dict:
        """True split execution over the transport layer (paper Fig. 2).

        Per step t the wire carries exactly four message kinds:
        ``head_fwd`` (batch row indices; arrow 4 "compute forward"),
        ``cut_activations`` (arrow 5), ``cut_gradients`` (arrow 7), and
        — in the sequential schedule only — ``step_done`` acks.  The
        pipelined schedule ships the cut gradients *before* the
        scientist's trunk update and the next forward request right
        behind them, so the owners' backward+forward for t/t+1 overlap
        the scientist's optimizer step; FIFO order keeps the math
        identical (owners always apply the step-t update before running
        batch t+1).  With the lossless codec, both schedules reproduce
        the joint program bit-for-bit whenever the adapter's head
        optimizer is elementwise-separable across owners (the paper's
        MLP/SGD case — property-tested); the LM adapter clips grads
        per-owner instead of across all heads, so it tracks the joint
        path within tolerance rather than exactly."""
        adapter = self.adapter
        if not getattr(adapter, "supports_split", False):
            raise ValueError(f"{type(adapter).__name__} does not support "
                             "split execution")
        if schedule not in ("pipelined", "sequential"):
            raise ValueError(f"unknown schedule {schedule!r}")
        sequential = schedule == "sequential"
        codec = transport.get_codec(compression)

        n = len(self.scientist.ids)
        n_train = n - int(n * eval_frac)
        if n_train < batch_size:
            raise ValueError(f"{n_train} train rows < batch {batch_size}")
        self._train_idx = np.arange(n_train)
        self._eval_idx = np.arange(n_train, n)

        trunk_step = adapter.trunk_program()
        trunk_opt = adapter.trunk_optimizer(scientist_lr)
        trunk_params = self.params["trunk"]
        trunk_state = trunk_opt.init(trunk_params)

        # update+apply compiled together — the joint step's fusion
        # granularity (bit-for-bit equivalence depends on it)
        @jax.jit
        def trunk_update(tp, ts, tg, i):
            updates, ts = trunk_opt.update(tg, ts, tp, i)
            return apply_updates(tp, updates), ts

        workers, eps, threads = [], [], []
        for p, owner in enumerate(self.owners):
            ep_sci, ep_own = transport.channel_pair(
                "scientist", owner.name, backend=backend,
                latency_s=latency_s, bandwidth_bps=bandwidth_bps)
            head_fwd, head_bwd = adapter.owner_programs(p)
            w = OwnerComputeEndpoint(
                owner, ep_own, head_fwd, head_bwd,
                optimizer=adapter.owner_optimizer(owner_lr),
                params=adapter.owner_param_slice(self.params, p),
                codec=codec, ack_steps=sequential)
            workers.append(w)
            eps.append(ep_sci)
            th = threading.Thread(target=w.run, daemon=True,
                                  name=f"owner-{owner.name}")
            th.start()
            threads.append(th)

        labels = self.scientist.labels
        rng = np.random.default_rng(self.seed if shuffle_seed is None
                                    else shuffle_seed)
        if epochs is not None:
            steps_per_epoch = (n_train - batch_size) // batch_size + 1
            total_steps = epochs * steps_per_epoch
        else:
            steps_per_epoch = None
            total_steps = steps
        # THE batch-index stream — shared with the joint loop
        gen = self._index_stream(rng, n_train, batch_size, epochs, steps)
        inflight: deque = deque()

        def send_fwd(idx, seq):
            for ep in eps:
                ep.send("head_fwd", {"idx": np.asarray(idx, np.int32)},
                        seq=seq)
            inflight.append(idx)

        def recv_cuts(seq):
            cuts, aux = [], 0.0
            for ep, w in zip(eps, workers):
                m = self._recv_from_owner(ep, w, "cut_activations")
                if m.seq != seq:
                    raise RuntimeError(f"protocol desync: cut seq {m.seq} "
                                       f"!= expected {seq}")
                cuts.append(codec.decode(m.payload))
                # scalar rides as a (1,) array (wire arrays are >=1-d)
                aux += float(np.asarray(m.payload.get("aux", 0.0)).sum())
            return jnp.asarray(np.stack(cuts)), aux

        history: dict = {"train": [], "eval": []}
        t0 = time.time()
        t_warm = None       # end of step 0 — everything compiled after it
        overhead_s = 0.0    # eval/sync/ckpt time, excluded from step cost
        metrics: dict = {}

        def scalars(m):
            return {k: float(v) for k, v in m.items()}

        try:
            if total_steps > 0:
                send_fwd(next(gen), 0)
            for t in range(total_steps):
                idx_t = inflight.popleft()
                cut, owner_aux = recv_cuts(t)
                lab = jnp.asarray(labels[idx_t])
                metrics, tgrads, cgrads = trunk_step(trunk_params, cut, lab)
                if owner_aux and "aux" in metrics:
                    # joint-path parity: heads aux + trunk aux
                    metrics = {**metrics,
                               "aux": metrics["aux"] + owner_aux}
                cg = np.asarray(cgrads)
                if sequential:
                    # synchronous baseline: update, ship grads, wait for
                    # every owner to finish its step, then request t+1
                    trunk_params, trunk_state = trunk_update(
                        trunk_params, trunk_state, tgrads, t)
                    for p, ep in enumerate(eps):
                        ep.send("cut_gradients", codec.encode(cg[p]), seq=t)
                    for ep, w in zip(eps, workers):
                        self._recv_from_owner(ep, w, "step_done")
                    if t + 1 < total_steps:
                        send_fwd(next(gen), t + 1)
                else:
                    # pipelined: grads + next forward request leave first;
                    # the owners' bwd(t)+fwd(t+1) overlap our trunk update
                    for p, ep in enumerate(eps):
                        ep.send("cut_gradients", codec.encode(cg[p]), seq=t)
                    if t + 1 < total_steps:
                        send_fwd(next(gen), t + 1)
                    trunk_params, trunk_state = trunk_update(
                        trunk_params, trunk_state, tgrads, t)
                if t == 0:
                    t_warm = time.time()

                # ----------- bookkeeping (excluded from step timings)
                tb = time.time()
                if epochs is not None:
                    if (t + 1) % steps_per_epoch == 0:
                        ep_i = (t + 1) // steps_per_epoch - 1
                        rec = {"epoch": ep_i, **scalars(metrics)}
                        history["train"].append(rec)
                        if len(self._eval_idx):
                            self._sync_split_params(workers, eps,
                                                    trunk_params)
                            history["eval"].append(
                                {"epoch": ep_i, **self.evaluate()})
                        if verbose and (ep_i % (log_every or 1) == 0
                                        or ep_i == epochs - 1):
                            ev = (history["eval"][-1]
                                  if history["eval"] else {})
                            extra = "".join(f" val_{k}={v:.4f}"
                                            for k, v in ev.items()
                                            if k != "epoch")
                            print(f"epoch {ep_i:3d} " + " ".join(
                                f"{k}={v:.4f}" for k, v in rec.items()
                                if k != "epoch") + extra +
                                f" ({time.time() - t0:.1f}s)")
                        if ckpt_dir and ckpt_every \
                                and (ep_i + 1) % ckpt_every == 0:
                            self._sync_split_params(workers, eps,
                                                    trunk_params)
                            self.checkpoint(ckpt_dir, ep_i + 1)
                else:
                    rec = {"step": t, **scalars(metrics)}
                    history["train"].append(rec)
                    if verbose and log_every and (t % log_every == 0
                                                  or t == steps - 1):
                        print(f"step {t:5d} " + " ".join(
                            f"{k}={v:.4f}" for k, v in rec.items()
                            if k != "step") + f" ({time.time() - t0:.1f}s)")
                    if ckpt_dir and ckpt_every \
                            and (t + 1) % ckpt_every == 0:
                        self._sync_split_params(workers, eps, trunk_params)
                        self.checkpoint(ckpt_dir, t + 1)
                overhead_s += time.time() - tb

            wall_s = time.time() - t0
            self._sync_split_params(workers, eps, trunk_params)
            if steps is not None and len(self._eval_idx):
                history["eval"].append({"step": steps, **self.evaluate()})
        finally:
            for ep in eps:
                ep.send("stop", {})
            for th in threads:
                th.join(timeout=10.0)

        # ------------------------------------- measured traffic accounting
        per_owner: Dict[str, dict] = {}
        tot_payload = tot_wire = 0
        for owner, ep in zip(self.owners, eps):
            sent, rcvd = ep.sent_stats, ep.recv_stats
            cut_k = rcvd["by_kind"].get("cut_activations",
                                        {"payload_bytes": 0,
                                         "wire_bytes": 0})
            grad_k = sent["by_kind"].get("cut_gradients",
                                         {"payload_bytes": 0,
                                          "wire_bytes": 0})
            per_owner[owner.name] = {
                "cut_payload_bytes": cut_k["payload_bytes"],
                "cut_wire_bytes": cut_k["wire_bytes"],
                "grad_payload_bytes": grad_k["payload_bytes"],
                "grad_wire_bytes": grad_k["wire_bytes"],
                "messages": sent["messages"] + rcvd["messages"],
            }
            tot_payload += cut_k["payload_bytes"] + grad_k["payload_bytes"]
            tot_wire += cut_k["wire_bytes"] + grad_k["wire_bytes"]
            self._log(owner.name, "scientist", "cut_activations",
                      bytes=cut_k["payload_bytes"], measured=True,
                      per_step_bytes=cut_k["payload_bytes"]
                      // max(total_steps, 1),
                      width=self.adapter.cut_shape(
                          batch_size, owner.feature_shape)[-1])
            self._log("scientist", owner.name, "cut_gradients",
                      bytes=grad_k["payload_bytes"], measured=True,
                      per_step_bytes=grad_k["payload_bytes"]
                      // max(total_steps, 1))
        self.transport_stats = {
            "mode": "split", "schedule": schedule,
            "compression": compression or "none", "backend": backend,
            "latency_s": latency_s, "bandwidth_bps": bandwidth_bps,
            "steps": total_steps, "wall_s": wall_s,
            # per-step cost excludes eval/sync/ckpt bookkeeping ...
            "step_ms": (1e3 * (wall_s - overhead_s)
                        / max(total_steps, 1)),
            # ... and, steady-state, the step-0 jit compiles too
            "steady_step_ms": (1e3 * (t0 + wall_s - t_warm - overhead_s)
                               / (total_steps - 1)
                               if t_warm is not None and total_steps > 1
                               else 1e3 * (wall_s - overhead_s)
                               / max(total_steps, 1)),
            "per_owner": per_owner,
            "cut_payload_bytes_per_step": sum(
                o["cut_payload_bytes"] for o in per_owner.values())
            // max(total_steps, 1),
            "total_payload_bytes": tot_payload,
            "total_wire_bytes": tot_wire,
            "total_payload_bytes_per_step": tot_payload
            // max(total_steps, 1),
        }

        final = dict(history["train"][-1]) if history["train"] else {}
        if history["eval"]:
            final.update({f"val_{k}": v
                          for k, v in history["eval"][-1].items()
                          if k not in ("epoch", "step")})
        history["final"] = final
        history["transport"] = self.transport_stats
        self.history = history
        return history

    # ------------------------------------------------------------ 4. eval

    def evaluate(self, *, split: str = "eval",
                 batch_size: int = 512) -> Dict[str, float]:
        """Metrics on the held-out (or train) rows, batched and
        length-weighted."""
        self._require(resolved=True, built=True, labels=True)
        idx = self._eval_idx if split == "eval" else self._train_idx
        if idx is None or not len(idx):
            raise ValueError(f"no rows in split {split!r} — "
                             "fit with eval_frac > 0 first")
        owner_arrays = self._owner_arrays()
        labels = self.scientist.labels
        totals: Dict[str, float] = {}
        n_done = 0
        for s in range(0, len(idx), batch_size):
            sub = idx[s:s + batch_size]
            m = self._eval_fn(self.params, self.adapter.make_batch(
                owner_arrays, labels, sub))
            for k, v in m.items():
                totals[k] = totals.get(k, 0.0) + float(v) * len(sub)
            n_done += len(sub)
        return {k: v / n_done for k, v in totals.items()}

    # ------------------------------------------------------------ 5. serve

    def serve(self, **engine_kw):
        """Wrap the resident split model in a ``ServingEngine`` (LM archs).
        Kwargs are forwarded: ``batch_slots, ctx_len, max_new, eos_token,
        ring_cache, pad_token``, plus the transport boundary knobs
        ``transport`` ("direct" | "queue" routes every cut activation
        through a measured ``federation.transport`` channel),
        ``latency_s``, and ``bandwidth_bps``."""
        self._require(built=True)
        if not getattr(self.adapter, "supports_serving", False):
            raise ValueError(
                f"{type(self.adapter).__name__} does not support serving")
        return self.adapter.make_engine(self.params, **engine_kw)

    def serve_dataset(self, *, max_new: int = 16, batch_slots: int = 4,
                      n_requests: Optional[int] = None, **engine_kw):
        """Serve the session's own aligned contexts: owners' sequence
        slices are merged (owner-side) into each request's context, queued,
        and decoded in waves.  Returns ({rid: Result}, engine)."""
        self._require(resolved=True, built=True)
        contexts = batching.merge_sequence_slices(
            np.stack(self._owner_arrays()))
        if n_requests is not None:
            contexts = contexts[:n_requests]
        engine = self.serve(batch_slots=batch_slots,
                            ctx_len=contexts.shape[1], max_new=max_new,
                            **engine_kw)
        for row in contexts:
            engine.submit(row)
        return engine.run(), engine

    # ---------------------------------------------------------- accounting

    def checkpoint(self, ckpt_dir: str, step: int = 0) -> str:
        """Per-party checkpoints: heads/owner{i}.npz + trunk.npz."""
        self._require(built=True)
        from repro import checkpoint as ckpt
        return ckpt.save_split(ckpt_dir, self.params, step)

    def cut_traffic(self, batch_size: int,
                    bytes_per_el: int = 4) -> Dict[str, int]:
        """Bytes crossing each owner<->scientist boundary per step (C4)."""
        self._require(built=True)
        shape = self.adapter.cut_shape(
            batch_size, self.owners[0].feature_shape)
        tokens = shape[1] if len(shape) == 3 else 1
        return cut_layer_traffic(len(self.owners), batch_size, tokens,
                                 shape[-1], bytes_per_el)
