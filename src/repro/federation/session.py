"""VerticalSession — the single entrypoint for every PyVertical workflow.

The paper's pipeline (Fig. 2) as a facade over the repo's machinery:

    sci, owners = feature_parties(*make_vertical_mnist_parties(2000))
    session = VerticalSession(sci, owners)
    session.resolve(group="modp512")          # DH-PSI + ID alignment
    session.build(CONFIG)                     # MLPSplitNN | SplitModel
    history = session.fit(epochs=10, batch_size=128, eval_frac=0.15)
    engine = session.serve(...)               # split-inference (LM archs)

Party-visibility contract (enforced, see ``tests/test_federation.py``):
owners never see labels, the scientist never receives raw feature arrays.
Every cross-party message the session mediates is appended to
``session.transcript``; during training the only owner->scientist payloads
are PSI responses and cut-layer activations (claim C4), and the only
scientist->owner payloads are blinded PSI sets, the resolved-ID broadcast,
and cut-layer gradients.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import numpy as np

from repro.core.psi import GROUPS, PSIClient, PSIServer
from repro.core.splitnn import (cut_layer_traffic, make_split_train_step,
                                train_state_init)
from repro.federation import batching
from repro.federation.parties import DataOwner, DataScientist, PrivacyError
from repro.federation.registry import build_adapter


class VerticalSession:
    """Orchestrates one scientist + N owners through resolve / build /
    fit / evaluate / serve.  The session itself is the trusted simulation
    runtime; party objects keep their raw data private."""

    def __init__(self, scientist: DataScientist,
                 owners: Union[Sequence[DataOwner], Dict[str, DataOwner]],
                 *, seed: int = 0):
        self.scientist = scientist
        self.owners: List[DataOwner] = (list(owners.values())
                                        if isinstance(owners, dict)
                                        else list(owners))
        if len({o.name for o in self.owners}) != len(self.owners):
            raise ValueError("owner names must be unique")
        if not self.owners:
            raise ValueError("need at least one data owner")
        self.seed = seed
        self.transcript: List[dict] = []
        self.resolve_stats: Optional[dict] = None
        self.adapter = None
        self.params = None
        self.history: Optional[dict] = None
        self._resolved = False
        self._eval_idx = np.arange(0)
        self._train_idx: Optional[np.ndarray] = None
        self._eval_fn = None

    # ------------------------------------------------------------- plumbing

    def _log(self, frm: str, to: str, kind: str, **payload):
        self.transcript.append({"from": frm, "to": to, "kind": kind,
                                **payload})

    def _owner_arrays(self) -> List[np.ndarray]:
        """Owner-side accessor: aligned per-owner feature matrices.  These
        arrays feed the jitted joint step (the simulation of owner-local
        head computation); they are never attached to the scientist."""
        return [o._features for o in self.owners]

    def _require(self, *, resolved=False, built=False, labels=False):
        if resolved and not self._resolved:
            raise RuntimeError("call session.resolve() before training — "
                               "parties are not ID-aligned yet")
        if built and self.adapter is None:
            raise RuntimeError("call session.build(config) first")
        if labels and not self.scientist.has_labels:
            raise PrivacyError("the scientist holds no labels; this "
                               "session supports inference only")

    # ------------------------------------------------------------ 1. resolve

    def resolve(self, *, group: str = "modp2048",
                fp_rate: float = 1e-9) -> dict:
        """The paper's §3.1 protocol: the scientist runs DH-PSI pairwise
        with each owner (scientist = client, so only the scientist learns
        each intersection), intersects globally, broadcasts the shared IDs,
        and every party filter-and-sorts.  Returns the stats dict."""
        nb = GROUPS[group][2]
        stats: dict = {"rounds": [], "global_intersection": 0}
        global_ids = set(self.scientist.ids)
        for owner in self.owners:
            client = PSIClient(self.scientist.ids, group)
            server = PSIServer(owner.ids, fp_rate, group)
            blinded = client.blind()
            double, bf = server.respond(blinded)
            inter = client.intersect(double, bf)
            global_ids &= set(inter)
            up, down = nb * len(blinded), nb * len(double) + bf.nbytes()
            self._log("scientist", owner.name, "psi_blinded", bytes=up)
            self._log(owner.name, "scientist", "psi_response", bytes=down,
                      width=None)
            stats["rounds"].append({
                "owner": owner.name, "intersection_size": len(inter),
                "client_upload_bytes": up, "server_response_bytes": down,
                "bloom_bytes": bf.nbytes()})
        stats["global_intersection"] = len(global_ids)
        self.scientist._align(global_ids)
        for owner in self.owners:
            owner._align(global_ids)
            self._log("scientist", owner.name, "resolved_ids",
                      count=len(global_ids))
            # invariant SplitNN training relies on: identical ID order
            assert owner.ids == self.scientist.ids, \
                f"misaligned owner {owner.name}"
        self._resolved = True
        self.resolve_stats = stats
        return stats

    # -------------------------------------------------------------- 2. build

    def build(self, config, *, seed: Optional[int] = None
              ) -> "VerticalSession":
        """Instantiate the split model for ``config`` via the registry
        (``MLPSplitConfig`` -> MLPSplitNN, ``ArchConfig`` -> SplitModel)
        and initialize per-party parameters."""
        self.adapter = build_adapter(config)
        key = jax.random.PRNGKey(self.seed if seed is None else seed)
        self.params = self.adapter.init(key)
        self._eval_fn = jax.jit(
            lambda p, b: self.adapter.loss_fn(p, b)[1])
        return self

    # ---------------------------------------------------------------- 3. fit

    def fit(self, *, epochs: Optional[int] = None,
            steps: Optional[int] = None, batch_size: int = 128,
            eval_frac: float = 0.0, owner_lr: Optional[float] = None,
            scientist_lr: Optional[float] = None,
            log_every: Optional[int] = None, ckpt_dir: Optional[str] = None,
            ckpt_every: int = 0, shuffle_seed: Optional[int] = None,
            verbose: bool = True) -> dict:
        """The jitted per-segment-optimizer training loop.

        Exactly one of ``epochs`` (feature workloads) / ``steps`` (LM
        workloads) must be given.  ``eval_frac`` holds out the last
        fraction of aligned rows; per-epoch (or final) eval metrics land
        in ``history["eval"]``.  ``ckpt_dir``+``ckpt_every`` write
        per-party checkpoints through ``repro.checkpoint.save_split``.
        Returns ``{"train": [...], "eval": [...], "final": {...}}``."""
        self._require(resolved=True, built=True, labels=True)
        if (epochs is None) == (steps is None):
            raise ValueError("pass exactly one of epochs= or steps=")

        n = len(self.scientist.ids)
        n_train = n - int(n * eval_frac)
        if n_train < batch_size:
            raise ValueError(f"{n_train} train rows < batch {batch_size}")
        self._train_idx = np.arange(n_train)
        self._eval_idx = np.arange(n_train, n)

        adapter = self.adapter
        opt = adapter.default_optimizer(owner_lr, scientist_lr)
        state = train_state_init(self.params, opt)
        step_fn = make_split_train_step(adapter.loss_fn, opt, donate=False)

        # the per-step protocol traffic, recorded once (static shapes)
        for owner in self.owners:
            shape = adapter.cut_shape(batch_size, owner.feature_shape)
            self._log(owner.name, "scientist", "cut_activations",
                      shape=shape, width=shape[-1], per_step=True)
            self._log("scientist", owner.name, "cut_gradients",
                      shape=shape, per_step=True)

        owner_arrays = self._owner_arrays()
        labels = self.scientist.labels
        rng = np.random.default_rng(self.seed if shuffle_seed is None
                                    else shuffle_seed)
        history: dict = {"train": [], "eval": []}
        t0 = time.time()
        metrics = {}

        def scalars(m):
            return {k: float(v) for k, v in m.items()}

        if epochs is not None:
            global_step = 0
            for ep in range(epochs):
                order = rng.permutation(self._train_idx)
                for s in range(0, n_train - batch_size + 1, batch_size):
                    batch = adapter.make_batch(
                        owner_arrays, labels, order[s:s + batch_size])
                    self.params, state, metrics = step_fn(
                        self.params, state, batch, global_step)
                    global_step += 1
                rec = {"epoch": ep, **scalars(metrics)}
                history["train"].append(rec)
                if len(self._eval_idx):
                    history["eval"].append(
                        {"epoch": ep, **self.evaluate()})
                if verbose and (ep % (log_every or 1) == 0
                                or ep == epochs - 1):
                    ev = history["eval"][-1] if history["eval"] else {}
                    extra = "".join(f" val_{k}={v:.4f}"
                                    for k, v in ev.items() if k != "epoch")
                    print(f"epoch {ep:3d} " + " ".join(
                        f"{k}={v:.4f}" for k, v in rec.items()
                        if k != "epoch") + extra +
                        f" ({time.time() - t0:.1f}s)")
                if ckpt_dir and ckpt_every and (ep + 1) % ckpt_every == 0:
                    self.checkpoint(ckpt_dir, ep + 1)
        else:
            order = rng.permutation(self._train_idx)
            cursor = 0
            for i in range(steps):
                if cursor + batch_size > n_train:
                    order = rng.permutation(self._train_idx)
                    cursor = 0
                idx = order[cursor:cursor + batch_size]
                cursor += batch_size
                batch = adapter.make_batch(owner_arrays, labels, idx)
                self.params, state, metrics = step_fn(
                    self.params, state, batch, i)
                rec = {"step": i, **scalars(metrics)}
                history["train"].append(rec)
                if verbose and log_every and (i % log_every == 0
                                              or i == steps - 1):
                    print(f"step {i:5d} " + " ".join(
                        f"{k}={v:.4f}" for k, v in rec.items()
                        if k != "step") + f" ({time.time() - t0:.1f}s)")
                if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
                    self.checkpoint(ckpt_dir, i + 1)
            if len(self._eval_idx):
                history["eval"].append({"step": steps, **self.evaluate()})

        final = dict(history["train"][-1]) if history["train"] else {}
        if history["eval"]:
            final.update({f"val_{k}": v
                          for k, v in history["eval"][-1].items()
                          if k not in ("epoch", "step")})
        history["final"] = final
        self.history = history
        return history

    # ------------------------------------------------------------ 4. eval

    def evaluate(self, *, split: str = "eval",
                 batch_size: int = 512) -> Dict[str, float]:
        """Metrics on the held-out (or train) rows, batched and
        length-weighted."""
        self._require(resolved=True, built=True, labels=True)
        idx = self._eval_idx if split == "eval" else self._train_idx
        if idx is None or not len(idx):
            raise ValueError(f"no rows in split {split!r} — "
                             "fit with eval_frac > 0 first")
        owner_arrays = self._owner_arrays()
        labels = self.scientist.labels
        totals: Dict[str, float] = {}
        n_done = 0
        for s in range(0, len(idx), batch_size):
            sub = idx[s:s + batch_size]
            m = self._eval_fn(self.params, self.adapter.make_batch(
                owner_arrays, labels, sub))
            for k, v in m.items():
                totals[k] = totals.get(k, 0.0) + float(v) * len(sub)
            n_done += len(sub)
        return {k: v / n_done for k, v in totals.items()}

    # ------------------------------------------------------------ 5. serve

    def serve(self, **engine_kw):
        """Wrap the resident split model in a ``ServingEngine`` (LM archs).
        Kwargs are forwarded: ``batch_slots, ctx_len, max_new, eos_token,
        ring_cache, pad_token``."""
        self._require(built=True)
        if not getattr(self.adapter, "supports_serving", False):
            raise ValueError(
                f"{type(self.adapter).__name__} does not support serving")
        return self.adapter.make_engine(self.params, **engine_kw)

    def serve_dataset(self, *, max_new: int = 16, batch_slots: int = 4,
                      n_requests: Optional[int] = None, **engine_kw):
        """Serve the session's own aligned contexts: owners' sequence
        slices are merged (owner-side) into each request's context, queued,
        and decoded in waves.  Returns ({rid: Result}, engine)."""
        self._require(resolved=True, built=True)
        contexts = batching.merge_sequence_slices(
            np.stack(self._owner_arrays()))
        if n_requests is not None:
            contexts = contexts[:n_requests]
        engine = self.serve(batch_slots=batch_slots,
                            ctx_len=contexts.shape[1], max_new=max_new,
                            **engine_kw)
        for row in contexts:
            engine.submit(row)
        return engine.run(), engine

    # ---------------------------------------------------------- accounting

    def checkpoint(self, ckpt_dir: str, step: int = 0) -> str:
        """Per-party checkpoints: heads/owner{i}.npz + trunk.npz."""
        self._require(built=True)
        from repro import checkpoint as ckpt
        return ckpt.save_split(ckpt_dir, self.params, step)

    def cut_traffic(self, batch_size: int,
                    bytes_per_el: int = 4) -> Dict[str, int]:
        """Bytes crossing each owner<->scientist boundary per step (C4)."""
        self._require(built=True)
        shape = self.adapter.cut_shape(
            batch_size, self.owners[0].feature_shape)
        tokens = shape[1] if len(shape) == 3 else 1
        return cut_layer_traffic(len(self.owners), batch_size, tokens,
                                 shape[-1], bytes_per_el)
