"""Crash-recovery benchmark (``BENCH_recovery.json``).

Two questions about the supervised federation runtime
(``federation/supervisor.py`` + ``fit(supervise=True)``):

  1. correctness under fire — a supervised split fit with a
     chaos-injected mid-run owner crash must finish with *bitwise* the
     fault-free run's final params (the ``bit_identical`` leaves are
     exactly gated per backend, like the transport suite's byte
     parity).  The same cell records how many recoveries the run
     needed (``n_recoveries``, exact).
  2. cost — what supervision itself costs while nothing fails (the
     marker/snapshot/heartbeat machinery rides the hot path:
     ``supervision_overhead_ratio`` = supervised / unsupervised step
     time, ratio-gated), and what one crash costs end to end (the
     faulted run's wall clock vs the clean supervised run's,
     timing-gated).

Writes ``BENCH_recovery.json`` and returns the usual CSV rows.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.configs.pyvertical_mnist import CONFIG
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, faults, feature_parties

#: committed-baseline gate geometry
GATE_N, GATE_BATCH, GATE_STEPS = 300, 64, 8
#: the injected fault: owner0 dies when it sees the step-4 forward
CRASH = faults.Fault("owner0", "crash", "head_fwd",
                     occurrence=None, step=4)


def _fit(backend: str, *, supervise: bool, fault=None):
    old = os.environ.pop(faults.CHAOS_ENV, None)
    if fault is not None:
        os.environ[faults.CHAOS_ENV] = faults.FaultPlan([fault]).to_env()
    try:
        sci, raw = make_vertical_mnist_parties(GATE_N, seed=0,
                                               keep_frac=0.9)
        s = VerticalSession(*feature_parties(sci, raw))
        s.resolve(group="modp512")
        s.build(CONFIG)
        s.fit(steps=GATE_STEPS, batch_size=GATE_BATCH, verbose=False,
              mode="split", backend=backend, supervise=supervise,
              timeout=60.0)
    finally:
        os.environ.pop(faults.CHAOS_ENV, None)
        if old is not None:
            os.environ[faults.CHAOS_ENV] = old
    import jax
    ts = s.transport_stats
    return {
        "leaves": [np.asarray(x)
                   for x in jax.tree_util.tree_leaves(s.params)],
        "step_ms": ts["steady_step_ms"],
        "wall_ms": 1e3 * ts["wall_s"],
        "recoveries": ts["recoveries"],
    }


def _identical(a, b) -> int:
    return int(len(a) == len(b)
               and all(np.array_equal(x, y) for x, y in zip(a, b)))


def run(out: str = "BENCH_recovery.json"):
    report: dict = {"config": {"n": GATE_N, "batch": GATE_BATCH,
                               "steps": GATE_STEPS,
                               "fault": "owner0 crash head_fwd@4"}}
    rows = []

    plain = _fit("queue", supervise=False)
    gate: dict = {
        "supervision_overhead_ratio": 1.0,   # filled from queue cell
        "unsupervised_step_ms": plain["step_ms"],
    }
    for backend in ("queue", "process"):
        clean = _fit(backend, supervise=True)
        faulted = _fit(backend, supervise=True, fault=CRASH)
        cell = {
            "bit_identical": _identical(clean["leaves"],
                                        faulted["leaves"]),
            "n_recoveries": faulted["recoveries"],
            "clean_step_ms": clean["step_ms"],
            "clean_wall_ms": clean["wall_ms"],
            "faulted_wall_ms": faulted["wall_ms"],
        }
        gate[backend] = cell
        if backend == "queue":
            gate["supervision_overhead_ratio"] = (
                clean["step_ms"] / max(plain["step_ms"], 1e-9))
        rows.append((f"recovery_{backend}_bit_identical",
                     cell["bit_identical"],
                     f"crash@4 recoveries={cell['n_recoveries']}"))
        rows.append((f"recovery_{backend}_clean_step",
                     round(1e3 * cell["clean_step_ms"], 1),
                     f"faulted_wall_ms={cell['faulted_wall_ms']:.0f}"))

    report["gate"] = gate
    rows.append(("recovery_supervision_overhead",
                 round(gate["supervision_overhead_ratio"], 3),
                 f"unsup_step_ms={plain['step_ms']:.2f}"))

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    return rows


def run_check(out: str = "BENCH_recovery.json"):
    return run(out)


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
