"""Many-owner process-runtime benchmark (``BENCH_parties.json``).

Two questions about the process-per-party backend
(``federation/process_transport.py`` + ``federation/runtime.py``):

  1. parity — a paired A/B of the *same* split fit (same data, seed,
     schedule) through the thread-backed queue backend and the spawned
     process backend.  The protocol is wire-identical by construction,
     so the gate asserts the measured cut/grad wire bytes are *exactly*
     equal across backends (``wire_bytes_equal`` must stay 1) and
     tracks both step times with the usual timing tolerance.
  2. scale-out — an owners x backend sweep (2/4/8 parties).  Owner head
     compute runs in separate interpreters under the process backend,
     so on a multi-core host the process/queue step-time ratio is the
     subsystem's payoff; this container exposes ~1 effective core, so
     the speedup lands in the ``informational`` subtree (recorded, not
     gated) unless >= 2 cores are visible at measurement time.

A/B runs are interleaved (queue, process, queue, process ...) and the
speedup is the median of per-pair ratios, so the box's minute-scale
throughput drift cancels.  Writes ``BENCH_parties.json`` and returns
the usual CSV rows.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.configs.base import SplitConfig
from repro.configs.pyvertical_mnist import CONFIG
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties
from repro.federation.transport import _effective_cores

#: the committed-baseline gate geometry (run_check re-measures at this
#: exact size so byte equality is byte identity)
GATE_N, GATE_BATCH, GATE_EPOCHS = 800, 128, 1


def _config(owners: int):
    if owners == CONFIG.split.n_owners:
        return CONFIG
    return dataclasses.replace(
        CONFIG, split=SplitConfig(
            n_owners=owners, cut_layer=1, combine="concat", cut_dim=64,
            owner_lr=0.01, scientist_lr=0.1))


def _fit(owners: int, backend: str, *, n, batch, epochs):
    sci, raw = make_vertical_mnist_parties(n, n_owners=owners, seed=0,
                                           keep_frac=0.9)
    s = VerticalSession(*feature_parties(sci, raw))
    s.resolve(group="modp512")
    s.build(_config(owners))
    s.fit(epochs=epochs, batch_size=batch, verbose=False, mode="split",
          backend=backend, timeout=300.0)
    ts = s.transport_stats
    return {
        "step_ms": ts["steady_step_ms"],
        "cut_wire_bytes": sum(v["cut_wire_bytes"]
                              for v in ts["per_owner"].values()),
        "grad_wire_bytes": sum(v["grad_wire_bytes"]
                               for v in ts["per_owner"].values()),
        "total_wire_bytes": ts["total_wire_bytes"],
    }


def _gate(pairs: int = 2):
    """The paired A/B parity section at the committed-baseline size."""
    q_ms, p_ms = [], []
    q = p = None
    for _ in range(pairs):
        q = _fit(2, "queue", n=GATE_N, batch=GATE_BATCH,
                 epochs=GATE_EPOCHS)
        p = _fit(2, "process", n=GATE_N, batch=GATE_BATCH,
                 epochs=GATE_EPOCHS)
        q_ms.append(q["step_ms"])
        p_ms.append(p["step_ms"])
    equal = int(q["cut_wire_bytes"] == p["cut_wire_bytes"]
                and q["grad_wire_bytes"] == p["grad_wire_bytes"]
                and q["total_wire_bytes"] == p["total_wire_bytes"])
    gate = {
        "queue_step_ms": float(np.median(q_ms)),
        "process_step_ms": float(np.median(p_ms)),
        "cut_wire_bytes_queue": q["cut_wire_bytes"],
        "cut_wire_bytes_process": p["cut_wire_bytes"],
        "grad_wire_bytes_queue": q["grad_wire_bytes"],
        "grad_wire_bytes_process": p["grad_wire_bytes"],
        # the parity invariant itself, as an exactly-gated byte metric
        "wire_bytes_equal": equal,
    }
    speedup = float(np.median(
        [a / max(b, 1e-9) for a, b in zip(q_ms, p_ms)]))
    return gate, speedup


def run(out: str = "BENCH_parties.json", *, sweep: bool = True,
        pairs: int = 2):
    cores = _effective_cores()
    report: dict = {"config": {"n": GATE_N, "batch": GATE_BATCH,
                               "epochs": GATE_EPOCHS, "pairs": pairs,
                               "owners_grid": [2, 4, 8]}}
    rows = []

    gate, speedup = _gate(pairs)
    report["gate"] = gate
    # the payoff metric: hard-gate only where it's physically possible
    # (>= 2 effective cores); informational on single-core boxes
    info = {"effective_cores": cores,
            "process_vs_queue_speedup": speedup}
    if cores >= 2:
        report["gate"]["process_vs_queue_speedup"] = speedup
    report["informational"] = info
    rows.append(("parties_gate_queue_step",
                 round(1e3 * gate["queue_step_ms"], 1), "owners=2"))
    rows.append(("parties_gate_process_step",
                 round(1e3 * gate["process_step_ms"], 1),
                 f"owners=2 speedup={speedup:.2f} cores={cores}"))
    rows.append(("parties_wire_bytes_equal", gate["wire_bytes_equal"],
                 "process == queue, exact"))

    if sweep:
        report["owners_sweep"] = {}
        for owners in (2, 4, 8):
            cell = {}
            for backend in ("queue", "process"):
                r = _fit(owners, backend, n=GATE_N, batch=GATE_BATCH,
                         epochs=GATE_EPOCHS)
                cell[backend] = r
                rows.append((f"parties_{owners}x_{backend}_step",
                             round(1e3 * r["step_ms"], 1),
                             f"wire={r['total_wire_bytes']}"))
            cell["speedup"] = (cell["queue"]["step_ms"]
                               / max(cell["process"]["step_ms"], 1e-9))
            report["owners_sweep"][str(owners)] = cell

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    return rows


def run_fast(out: str = "BENCH_parties.json"):
    return run(out, sweep=False, pairs=1)


def run_check(out: str = "BENCH_parties.json"):
    """The bench-check section: gate geometry only, no sweep."""
    return run(out, sweep=False, pairs=2)


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
