"""Combine-strategy ablation — the Ceballos et al. (2020) comparison the
paper cites in §2.3 (they study multiple ways to merge head outputs; the
paper uses concat).  Same data, same budget, four combine modes, plus the
paper's §5.1 imbalanced-split future-work case.

Rows: (name, us_per_call=us per step, derived=val accuracy).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SplitConfig
from repro.configs.pyvertical_mnist import CONFIG, MLPSplitConfig
from repro.core.splitnn import (MLPSplitNN, make_split_train_step,
                                train_state_init)
from repro.data import make_mnist_like
from repro.optim import multi_segment, sgd


def _train(cfg, X, y, epochs=10, seed=0):
    model = MLPSplitNN(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    opt = multi_segment({"heads": sgd(cfg.split.owner_lr),
                         "trunk": sgd(cfg.split.scientist_lr)})
    state = train_state_init(params, opt)
    step = make_split_train_step(model.loss_fn, opt, donate=False)
    n = len(y)
    ntr = int(n * 0.85)
    if model.symmetric:
        xs_all = np.stack(np.split(X, model.P, axis=1))
        slice_fn = lambda idx: jnp.asarray(xs_all[:, idx])
    else:
        cuts = np.cumsum(model.splits)[:-1]
        parts = np.split(X, cuts, axis=1)
        slice_fn = lambda idx: [jnp.asarray(p[idx]) for p in parts]
    rng = np.random.default_rng(seed)
    t_tot = n_steps = 0
    for ep in range(epochs):
        order = rng.permutation(ntr)
        for s in range(0, ntr - 128, 128):
            idx = order[s:s + 128]
            b = {"x_slices": slice_fn(idx), "labels": jnp.asarray(y[idx])}
            t0 = time.perf_counter()
            params, state, m = step(params, state, b, ep)
            jax.block_until_ready(m["loss"])
            t_tot += time.perf_counter() - t0
            n_steps += 1
    vb = {"x_slices": slice_fn(np.arange(ntr, n)),
          "labels": jnp.asarray(y[ntr:])}
    _, vm = model.loss_fn(params, vb)
    return 1e6 * t_tot / n_steps, float(vm["accuracy"])


def run(n=3000, epochs=10):
    X, y = make_mnist_like(n, 0)
    rows = []
    for combine in ("concat", "sum", "mean", "max"):
        cfg = dataclasses.replace(
            CONFIG, split=dataclasses.replace(CONFIG.split, combine=combine))
        us, acc = _train(cfg, X, y, epochs)
        rows.append((f"combine_{combine}", round(us, 1), acc))
    # imbalanced vertical datasets (paper §5.1): 588/196 feature split
    cfg = MLPSplitConfig(feature_splits=(588, 196),
                         split=SplitConfig(n_owners=2, combine="concat",
                                           cut_dim=64, owner_lr=0.01,
                                           scientist_lr=0.1))
    us, acc = _train(cfg, X, y, epochs)
    rows.append(("combine_concat_imbalanced_75_25", round(us, 1), acc))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
