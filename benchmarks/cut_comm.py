"""Cut-layer communication accounting (the SplitNN efficiency argument,
§2.2: cross-party traffic is ONE activation + ONE gradient per step).

Reports bytes/step crossing each owner<->scientist boundary for the
paper's MLP, for combine-strategy variants (Ceballos et al. comparison),
and for the production text archs at train_4k — the quantity the
multi-pod roofline's cross-pod collective term measures.

Rows: (name, us_per_call=0 [static analysis], derived=MiB per step).
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.splitnn import cut_layer_traffic


def run():
    rows = []
    # the paper's MLP: batch 128, 64-dim cut, fp32
    t = cut_layer_traffic(2, 128, 1, 64, 4)
    rows.append(("cut_mlp_paper_concat", 0.0,
                 round(t["total_per_step_bytes"] / 2 ** 20, 4)))
    # sum/mean/max combine move the same per-owner tensor
    rows.append(("cut_mlp_paper_sum", 0.0,
                 round(t["total_per_step_bytes"] / 2 ** 20, 4)))
    # production archs, train_4k (B=256, S=4096, bf16)
    for arch in ("llama3.2-3b", "gemma2-9b", "llama3-405b", "zamba2-2.7b"):
        cfg = get_config(arch)
        P = cfg.split.n_owners
        t = cut_layer_traffic(P, 256, 4096 // P, cfg.d_model, 2)
        rows.append((f"cut_{arch}_train4k", 0.0,
                     round(t["total_per_step_bytes"] / 2 ** 20, 1)))
    # the cut-dim bottleneck lever (beyond-paper, privacy + bandwidth)
    cfg = get_config("llama3.2-3b")
    for k in (3072, 1024, 256):
        t = cut_layer_traffic(2, 256, 2048, k, 2)
        rows.append((f"cut_llama3.2-3b_k{k}", 0.0,
                     round(t["total_per_step_bytes"] / 2 ** 20, 1)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
