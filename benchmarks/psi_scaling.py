"""PSI benchmark (the paper's §2.1/§3.1 claim: DH-PSI with Bloom-filter
compression reduces communication).  Times one full PSI round per set size
and reports the compression ratio of the server response vs the naive
(uncompressed double-masked set) protocol.

Rows: (name, us_per_call=us per PSI round, derived=compression ratio).
"""
from __future__ import annotations

import time

from repro.core.psi import psi_intersect


def run(sizes=(128, 512, 2048), overlap=0.5, group="modp512"):
    rows = []
    for n in sizes:
        client = [f"id-{i}" for i in range(n)]
        server = [f"id-{i + int(n * (1 - overlap))}" for i in range(n)]
        t0 = time.perf_counter()
        inter, stats = psi_intersect(client, server, group=group)
        dt = time.perf_counter() - t0
        expect = len(set(client) & set(server))
        assert len(inter) == expect, "PSI mismatch"
        ratio = (stats["uncompressed_server_set_bytes"]
                 / max(stats["bloom_bytes"], 1))
        rows.append((f"psi_round_n{n}", 1e6 * dt, round(ratio, 2)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
