"""PSI benchmark (the paper's §2.1/§3.1 claim: DH-PSI with Bloom-filter
compression reduces communication).  Times one full PSI round per set size
and reports the compression ratio of the server response vs the naive
(uncompressed double-masked set) protocol, plus the hot-loop levers this
repo applies:

  * short (256-bit) exponents vs full-width — the per-leg modexp cost is
    linear in exponent bits;
  * blinded-set reuse — the marginal cost of adding one more owner round
    to a session whose client leg is already paid.

Writes ``BENCH_psi.json`` (tracked by ``benchmarks/run.py --check`` the
same way transport perf is) and returns the usual CSV rows
(name, us_per_call, derived).
"""
from __future__ import annotations

import json
import time

from repro.core.psi import PSIClient, PSIServer, psi_intersect


def run(sizes=(128, 512, 2048), overlap=0.5, group="modp512",
        out="BENCH_psi.json"):
    report: dict = {"config": {"sizes": list(sizes), "overlap": overlap,
                               "group": group},
                    "rounds": {}}
    rows = []
    for n in sizes:
        client = [f"id-{i}" for i in range(n)]
        server = [f"id-{i + int(n * (1 - overlap))}" for i in range(n)]
        t0 = time.perf_counter()
        inter, stats = psi_intersect(client, server, group=group)
        dt = time.perf_counter() - t0
        expect = len(set(client) & set(server))
        assert len(inter) == expect, "PSI mismatch"
        ratio = (stats["uncompressed_server_set_bytes"]
                 / max(stats["bloom_bytes"], 1))
        report["rounds"][str(n)] = {
            "round_ms": 1e3 * dt,
            "ids_per_s": n / dt,
            "compression_ratio": ratio,
            "bloom_bytes": stats["bloom_bytes"],
        }
        rows.append((f"psi_round_n{n}", 1e6 * dt, round(ratio, 2)))

    # lever 1: short vs full-width exponents (one mid-size round each)
    n = sizes[len(sizes) // 2]
    client = [f"id-{i}" for i in range(n)]
    server = [f"id-{i + n // 2}" for i in range(n)]
    t0 = time.perf_counter()
    psi_intersect(client, server, group=group, exp_bits=None)
    full_dt = time.perf_counter() - t0
    t0 = time.perf_counter()
    psi_intersect(client, server, group=group)
    short_dt = time.perf_counter() - t0
    report["short_exponent_speedup"] = full_dt / max(short_dt, 1e-9)
    rows.append(("psi_short_exp_round", 1e6 * short_dt,
                 f"speedup={report['short_exponent_speedup']:.2f}x"))

    # lever 2: blinded-set reuse — marginal cost of a second owner round
    cl = PSIClient(client, group)
    t0 = time.perf_counter()
    blinded = cl.blind()
    sv1 = PSIServer(server, group=group)
    cl.intersect(*sv1.respond(blinded))
    first_dt = time.perf_counter() - t0
    t0 = time.perf_counter()
    blinded = cl.blind()                       # memoized — free
    sv2 = PSIServer(server, group=group)
    cl.intersect(*sv2.respond(blinded))
    second_dt = time.perf_counter() - t0
    report["owner_round_amortization"] = first_dt / max(second_dt, 1e-9)
    rows.append(("psi_second_owner_round", 1e6 * second_dt,
                 f"first_round_ratio="
                 f"{report['owner_round_amortization']:.2f}"))

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
