"""PSI scaling benchmark — the entity-resolution gate every vertical
workload passes through before a single training step runs (ISSUE 4).

Measures the streaming/parallel engine (``repro.core.psi``) on three
axes and writes ``BENCH_psi.json``:

  * ``trajectory`` — full-size round time + peak RSS at 1e4/1e5/1e6 IDs
    (each size in its own subprocess so ``ru_maxrss`` is a clean per-size
    peak).  The bounded-memory claim lives here: RSS grows with the
    packed at-rest buffers (nb bytes/element) + the sharded Bloom, never
    with a full set of boxed big ints.  Also records the 1e5 comparison
    the acceptance gate names: parallel vs the serial engine (same run,
    same host) and vs the committed PR 3 round rate.
  * ``gate`` — a CI-sized re-measurable section (``--check`` re-runs it
    against the committed values with the tolerances in
    ``benchmarks.check``): round time, serial-vs-parallel speedup,
    deterministic protocol bytes, and the owner-round amortization
    (marginal second-owner round with the blinded set + Bloom cached).
  * ``wire_gate`` — resolve-over-wire (ISSUE 5): the in-process engine
    vs ``backend="queue"`` (the ``federation.psi_transport`` actors) at
    0 ms and 8 ms injected one-way latency, interleaved min-of-N trials.
    Asserts on every run that pipelined chunking amortizes the latency:
    the 8 ms round adds far less than the sequential floor of
    ``n_chunks x RTT``, and that a repeat round with the same owner
    transfers zero blind-upload bytes (measured, exact-checked).
  * ``delta_gate`` — streaming-population resolution (ISSUE 10): after
    1% ID churn the repeat resolve must stay O(Δ) — hard-asserted at
    <= 0.05x the full round's modexp ops and wire bytes on every run,
    with the op/byte counts exact-checked against the committed
    baseline.  Carries an informational hidden-mode overhead row.
  * ``wire_sweep`` — latency x chunk_size wall-clock rows (full runs
    only; informational, skipped by ``--check``).
  * the engine's invariant — the parallel/chunked round is bit-identical
    to the serial path (and, in the wire sections, to the transport
    engine) — is asserted on every run, not just reported.

CLI (also driven by ``benchmarks.run``):

    PYTHONPATH=src python -m benchmarks.psi_scaling            # full
    PYTHONPATH=src python -m benchmarks.psi_scaling --fast     # CI-sized
    PYTHONPATH=src python -m benchmarks.psi_scaling --one-size 10000
"""
from __future__ import annotations

import argparse
import json
import resource
import subprocess
import sys
import time

from repro.core.modexp import ModexpPool
from repro.core.psi import PSIClient, PSIServer, psi_round

#: committed PR 3 round rate (ids_per_s at n=2048, modp512, overlap 0.5,
#: serial short-blind/full-unblind engine) — the baseline the ISSUE 4
#: acceptance gate compares against.
PR3_IDS_PER_S = 464.885

DEFAULT_CHUNK = 4096
DEFAULT_PAR = 2


def _mk_sets(n, overlap):
    client = [f"id-{i}" for i in range(n)]
    server = [f"id-{i + int(n * (1 - overlap))}" for i in range(n)]
    return client, server


def _one_round(n, overlap, group, chunk_size, parallelism, pool=None,
               mode="noinv"):
    """One fresh full round (new secrets, nothing cached).  Returns
    (seconds, intersection, stats)."""
    cl_items, sv_items = _mk_sets(n, overlap)
    client = PSIClient(cl_items, group, mode=mode)
    server = PSIServer(sv_items, group=group)
    own = pool is None
    pool = pool or ModexpPool(parallelism)
    try:
        t0 = time.perf_counter()
        inter, stats = psi_round(client, server, pool=pool,
                                 chunk_size=chunk_size)
        dt = time.perf_counter() - t0
    finally:
        if own:
            pool.close()
    expect = len(set(cl_items) & set(sv_items))
    assert len(inter) == expect, "PSI mismatch"
    return dt, inter, stats


def measure_size(n, overlap=0.5, group="modp512",
                 chunk_size=DEFAULT_CHUNK, parallelism=DEFAULT_PAR,
                 mode="noinv"):
    """One trajectory row (run this in a subprocess for a clean RSS)."""
    dt, _, stats = _one_round(n, overlap, group, chunk_size, parallelism,
                              mode=mode)
    # parent RSS + the largest (reaped) pool worker's RSS: the aggregate
    # peak is ~ parent + parallelism * worker — both are reported so the
    # bounded-memory claim covers the whole process tree, not just the
    # parent (_one_round closes the pool, so children are reaped here)
    peak_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    child_mb = (resource.getrusage(resource.RUSAGE_CHILDREN).ru_maxrss
                / 1024.0)
    row = {
        "round_ms": 1e3 * dt,
        "ids_per_s": n / dt,
        "peak_rss_mb": peak_mb,
        "worker_peak_rss_mb": child_mb,
        "n_chunks": stats["n_chunks"],
        "server_response_bytes": stats["server_response_bytes"],
    }
    if mode == "bloom":
        row["bloom_bytes"] = stats["bloom_bytes"]
        row["bloom_shards"] = stats["bloom_shards"]
        row["compression_ratio"] = (stats["uncompressed_server_set_bytes"]
                                    / max(stats["bloom_bytes"], 1))
    return row


def _measure_size_subprocess(n, **kw):
    """Run ``measure_size`` in a child so ru_maxrss is per-size."""
    cmd = [sys.executable, "-m", "benchmarks.psi_scaling",
           "--one-size", str(n)]
    for k, v in kw.items():
        cmd += [f"--{k.replace('_', '-')}", str(v)]
    out = subprocess.run(cmd, capture_output=True, text=True, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def _gate_section(gate_n, overlap, group, chunk_size, parallelism):
    """The re-measurable CI section: serial vs parallel + amortization
    (default noinv engine) plus one bloom-variant round, with the
    bit-identity invariant asserted."""
    # serial and parallel rounds with SHARED secrets -> bit-identity
    cl_items, sv_items = _mk_sets(gate_n, overlap)
    client = PSIClient(cl_items, group)
    server = PSIServer(sv_items, group=group)
    t0 = time.perf_counter()
    ser_inter, _ = psi_round(client, server, chunk_size=chunk_size)
    serial_s = time.perf_counter() - t0
    client.reset_session()
    server.reset_session()
    with ModexpPool(parallelism) as pool:
        t0 = time.perf_counter()
        par_inter, stats = psi_round(client, server, pool=pool,
                                     chunk_size=chunk_size)
        parallel_s = time.perf_counter() - t0
        assert par_inter == ser_inter, \
            "parallel engine diverged from the serial path"

        # marginal second-owner round: blinded set already paid for
        sv2 = PSIServer([f"id-{i + gate_n // 4}" for i in range(gate_n)],
                        group=group)
        t0 = time.perf_counter()
        psi_round(client, sv2, pool=pool, chunk_size=chunk_size)
        marginal_s = time.perf_counter() - t0

        # the wire-compressed variant, same sizes (keeps the sharded
        # bloom machinery under the regression gate)
        bloom_s, _, bstats = _one_round(gate_n, overlap, group,
                                        chunk_size, parallelism,
                                        pool=pool, mode="bloom")
    return {
        "n": gate_n,
        "serial_round_ms": 1e3 * serial_s,
        "parallel_round_ms": 1e3 * parallel_s,
        "ids_per_s": gate_n / parallel_s,
        "speedup_vs_serial": serial_s / max(parallel_s, 1e-9),
        "owner_round_amortization": parallel_s / max(marginal_s, 1e-9),
        "marginal_owner_round_ms": 1e3 * marginal_s,
        "server_set_bytes": stats["server_set_bytes"],
        "n_chunks": stats["n_chunks"],
        "peak_inflight_elements": stats["peak_inflight_elements"],
        "bloom_mode": {
            "round_ms": 1e3 * bloom_s,
            "bloom_bytes": bstats["bloom_bytes"],
            "bloom_shards": bstats["bloom_shards"],
            "compression_ratio": (bstats["uncompressed_server_set_bytes"]
                                  / max(bstats["bloom_bytes"], 1)),
        },
    }


def _wire_round(n, overlap, group, chunk_size, latency_s, *,
                client=None, worker=None):
    """One resolve-over-wire round (``federation.psi_transport``).
    Fresh parties unless ``client``/``worker`` are passed (repeat-round
    reuse).  Returns (seconds, intersection, wire_stats,
    client_endpoint, client, worker)."""
    import threading

    from repro.core.psi import PSIClient, PSIServer
    from repro.federation import transport
    from repro.federation.psi_transport import (PSIServerEndpoint,
                                                wire_psi_round)

    cl_items, sv_items = _mk_sets(n, overlap)
    if client is None:
        client = PSIClient(cl_items, group)
    if worker is None:
        server = PSIServer(sv_items, group=group)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue",
                                        latency_s=latency_s)
    if worker is None:
        worker = PSIServerEndpoint("owner0", server, ep_s)
    else:
        # same actor, fresh channel: the owner-side caches persist
        worker = PSIServerEndpoint("owner0", worker.server, ep_s,
                                   blind_cache=worker._blind_cache)
    th = threading.Thread(target=worker.run, daemon=True)
    th.start()
    try:
        t0 = time.perf_counter()
        inter, stats = wire_psi_round(client, ep_c, worker=worker,
                                      chunk_size=chunk_size)
        dt = time.perf_counter() - t0
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    expect = len(set(cl_items) & set(sv_items))
    assert len(inter) == expect, "wire PSI mismatch"
    return dt, inter, stats, ep_c, client, worker


def _wire_gate_section(n=256, overlap=0.5, group="modp512",
                       chunk_size=16, latency_s=8e-3, trials=3):
    """Resolve-over-wire gate: in-process vs queue at 0 ms and the
    injected latency, interleaved min-of-``trials`` (this host's
    throughput drifts ~25% between runs — see ROADMAP).  Hard-asserts
    the two properties the wire engine exists for: pipelined chunks
    amortize latency (wall-clock far under sequential chunks x RTT) and
    the blinded upload is reused across owner rounds (zero re-upload
    bytes, measured)."""
    n_chunks = -(-n // chunk_size)
    direct_s, q0_s, qlat_s = [], [], []
    inters = set()
    for _ in range(trials):
        dt, inter, _ = _one_round(n, overlap, group, chunk_size, 0)
        direct_s.append(dt)
        inters.add(tuple(inter))
        dt, inter, _, _, _, _ = _wire_round(n, overlap, group, chunk_size,
                                            0.0)
        q0_s.append(dt)
        inters.add(tuple(inter))
        dt, inter, _, _, _, _ = _wire_round(n, overlap, group, chunk_size,
                                            latency_s)
        qlat_s.append(dt)
        inters.add(tuple(inter))
    assert len(inters) == 1, \
        "wire engine diverged from the in-process path"

    # repeat round against the SAME owner: the server caches the upload
    # by content tag, so round 2 ships zero psi_blind_chunk bytes
    _, _, st1, ep1, client, worker = _wire_round(n, overlap, group,
                                                 chunk_size, 0.0)
    up1 = ep1.sent_stats["by_kind"]["psi_blind_chunk"]["wire_bytes"]
    t0 = time.perf_counter()
    _, _, st2, ep2, _, _ = _wire_round(n, overlap, group, chunk_size, 0.0,
                                       client=client, worker=worker)
    repeat_s = time.perf_counter() - t0
    up2 = ep2.sent_stats["by_kind"].get(
        "psi_blind_chunk", {"wire_bytes": 0})["wire_bytes"]
    assert st2["upload_skipped"] and up2 == 0, \
        "blinded-upload reuse lost on the wire"

    direct, q0, qlat = min(direct_s), min(q0_s), min(qlat_s)
    seq_floor = n_chunks * 2 * latency_s          # one RTT per chunk
    added = qlat - q0
    assert added < 0.6 * seq_floor, \
        (f"pipelined chunking no longer amortizes latency: "
         f"{1e3 * added:.0f} ms added vs sequential floor "
         f"{1e3 * seq_floor:.0f} ms")
    return {
        "n": n, "chunk_size": chunk_size, "n_chunks": n_chunks,
        "latency_ms": 1e3 * latency_s,
        "direct_round_ms": 1e3 * direct,
        "queue_round_ms": 1e3 * q0,
        "queue_latency_round_ms": 1e3 * qlat,
        "sequential_floor_ms": 1e3 * seq_floor,
        # headroom >= 1: what a chunk-synchronous client would pay at
        # this latency, over what the pipelined round measured
        "latency_amortization": (q0 + seq_floor) / max(qlat, 1e-9),
        "repeat_round_ms": 1e3 * repeat_s,
        "upload_wire_bytes": up1,
        "repeat_upload_wire_bytes": up2,
        "round_upload_bytes": st1["client_upload_bytes"],
    }


def _hidden_wire_round(n, overlap, group, chunk_size):
    """One fresh ``mode="hidden"`` resolve over the queue backend.
    Returns (seconds, stats, client_endpoint).  The intersection is a
    padded pseudonym list, so the caller checks ``hidden_kept`` rather
    than raw membership."""
    import threading

    from repro.federation import transport
    from repro.federation.psi_transport import (PSIServerEndpoint,
                                                wire_psi_round)

    cl_items, sv_items = _mk_sets(n, overlap)
    client = PSIClient(cl_items, group, mode="hidden")
    server = PSIServer(sv_items, group=group)
    ep_c, ep_s = transport.channel_pair("scientist", "owner0",
                                        backend="queue")
    worker = PSIServerEndpoint("owner0", server, ep_s)
    th = threading.Thread(target=worker.run, daemon=True)
    th.start()
    try:
        t0 = time.perf_counter()
        inter, stats = wire_psi_round(client, ep_c, worker=worker,
                                      chunk_size=chunk_size)
        dt = time.perf_counter() - t0
    finally:
        ep_c.send("psi_stop", {})
        th.join(timeout=10.0)
    assert len(inter) == stats["hidden_kept"]
    return dt, stats, ep_c


def _delta_gate_section(n=10_000, churn_frac=0.01, overlap=0.5,
                        group="modp512", chunk_size=DEFAULT_CHUNK):
    """Delta-resolution gate (ISSUE 10): after churning ``churn_frac``
    of a streaming population, the repeat resolve must cost O(Δ) —
    hard-asserted at <= 0.05x the full round's modexp ops AND wire
    bytes, with the aligned IDs bit-identical to a from-scratch client.
    Byte counts and op counts are exact-checked by ``benchmarks.check``;
    the ``informational`` hidden-mode overhead row is skipped by
    ``--check`` (wall-clock only)."""
    d = max(1, int(n * churn_frac))

    # round 1: fresh parties, full protocol
    dt_full, _, st1, ep1, client, worker = _wire_round(
        n, overlap, group, chunk_size, 0.0)
    full_ops = st1["client_modexp_ops"] + st1["server_modexp_ops"]
    full_up = ep1.sent_stats["wire_bytes"]
    full_wire = full_up + ep1.recv_stats["wire_bytes"]

    # churn: drop the first d ids, append d fresh ones (both outside the
    # server set at overlap 0.5, so the intersection itself is unchanged
    # and _wire_round's from-scratch expectation still certifies it)
    new_ids = ([f"id-{i}" for i in range(d, n)]
               + [f"fresh-{i}" for i in range(d)])
    ops0 = client.ops
    client.update_items(new_ids)
    update_ops = client.ops - ops0      # only the d added ids blind
    dt_delta, inter2, st2, ep2, client, worker = _wire_round(
        n, overlap, group, chunk_size, 0.0, client=client, worker=worker)
    assert st2["delta_used"] and st2["server_leg_skipped"], \
        "delta resolution path lost (full re-upload happened)"
    delta_ops = (update_ops + st2["client_modexp_ops"]
                 + st2["server_modexp_ops"])
    delta_up = ep2.sent_stats["wire_bytes"]
    delta_wire = delta_up + ep2.recv_stats["wire_bytes"]

    # bit-identity: a from-scratch client over the churned population
    # resolves to the same IDs through the in-process engine
    ref_inter, _ = psi_round(PSIClient(list(client.items), group),
                             worker.server, chunk_size=chunk_size)
    assert sorted(inter2) == sorted(ref_inter), \
        "delta round diverged from a from-scratch resolve"

    ops_share = delta_ops / max(full_ops, 1)
    wire_share = delta_wire / max(full_wire, 1)
    assert ops_share <= 0.05, \
        (f"delta resolve is no longer O(Δ) in modexp ops: "
         f"{delta_ops} vs full {full_ops} ({ops_share:.3f} > 0.05)")
    assert wire_share <= 0.05, \
        (f"delta resolve is no longer O(Δ) in wire bytes: "
         f"{delta_wire} vs full {full_wire} ({wire_share:.3f} > 0.05)")

    # informational: what membership hiding costs over noinv, same size
    hn, hc = 2000, 256
    noi_dt, _, noi_st, _, noi_cl, _ = _wire_round(hn, overlap, group,
                                                  hc, 0.0)
    hid_dt, hid_st, hid_ep = _hidden_wire_round(hn, overlap, group, hc)
    return {
        "n": n, "churn": d, "chunk_size": chunk_size,
        "full_round_ms": 1e3 * dt_full,
        "delta_round_ms": 1e3 * dt_delta,
        "full_modexp_ops": full_ops,
        "delta_modexp_ops": delta_ops,
        "full_wire_bytes": full_wire,
        "delta_wire_bytes": delta_wire,
        "full_upload_wire_bytes": full_up,
        "delta_upload_wire_bytes": delta_up,
        "delta_ops_share": ops_share,
        "delta_wire_share": wire_share,
        "informational": {
            "hidden_n": hn,
            "hidden_round_ms": 1e3 * hid_dt,
            "noinv_round_ms": 1e3 * noi_dt,
            "hidden_overhead": hid_dt / max(noi_dt, 1e-9),
            "hidden_wire_bytes": (hid_ep.sent_stats["wire_bytes"]
                                  + hid_ep.recv_stats["wire_bytes"]),
            "hidden_kept": hid_st["hidden_kept"],
        },
    }


def _wire_sweep(n=1024, overlap=0.5, group="modp512",
                latencies=(0.0, 2e-3, 8e-3), chunks=(32, 128, 512)):
    """latency x chunk_size wall-clock surface (informational)."""
    sweep = {}
    for lat in latencies:
        for c in chunks:
            dt, _, stats, _, _, _ = _wire_round(n, overlap, group, c, lat)
            sweep[f"lat{1e3 * lat:g}ms_chunk{c}"] = {
                "round_ms": 1e3 * dt,
                "n_chunks": stats["n_chunks"],
            }
    return sweep


def run(sizes=(10_000, 100_000, 1_000_000), overlap=0.5, group="modp512",
        chunk_size=DEFAULT_CHUNK, parallelism=DEFAULT_PAR,
        gate_n=10_000, compare_n=100_000, trajectory=True,
        out="BENCH_psi.json"):
    """Full benchmark.  ``trajectory=False`` (the ``--check`` shape)
    re-measures only the gate section; the committed trajectory is
    informational for the checker (``SKIP_SUBTREES``)."""
    report: dict = {"config": {
        "sizes": list(sizes), "overlap": overlap, "group": group,
        "chunk_size": chunk_size, "parallelism": parallelism,
        "pr3_ids_per_s": PR3_IDS_PER_S}}
    rows = []

    report["gate"] = g = _gate_section(gate_n, overlap, group, chunk_size,
                                       parallelism)
    rows.append((f"psi_gate_n{gate_n}", 1e3 * g["parallel_round_ms"],
                 f"speedup_vs_serial={g['speedup_vs_serial']:.2f}x"))
    rows.append((f"psi_marginal_owner_n{gate_n}",
                 1e3 * g["marginal_owner_round_ms"],
                 f"amortization={g['owner_round_amortization']:.2f}x"))

    report["wire_gate"] = w = _wire_gate_section(group=group)
    rows.append((f"psi_wire_n{w['n']}",
                 1e3 * w["queue_latency_round_ms"],
                 f"latency_amortization={w['latency_amortization']:.2f}x "
                 f"reuse_upload={w['repeat_upload_wire_bytes']}B"))

    report["delta_gate"] = dg = _delta_gate_section(
        n=gate_n, group=group, chunk_size=chunk_size)
    rows.append((f"psi_delta_n{dg['n']}", dg["delta_round_ms"],
                 f"ops_share={dg['delta_ops_share']:.4f} "
                 f"wire_share={dg['delta_wire_share']:.4f} "
                 f"hidden_overhead="
                 f"{dg['informational']['hidden_overhead']:.2f}x"))

    if trajectory:
        report["wire_sweep"] = _wire_sweep(group=group)

    if trajectory:
        traj: dict = {}
        for n in sizes:
            row = _measure_size_subprocess(
                n, overlap=overlap, group=group, chunk_size=chunk_size,
                parallelism=parallelism)
            row["speedup_vs_pr3_committed"] = (row["ids_per_s"]
                                               / PR3_IDS_PER_S)
            traj[str(n)] = row
            rows.append((f"psi_round_n{n}", 1e3 * row["round_ms"],
                         f"peak_rss={row['peak_rss_mb']:.0f}MB "
                         f"vs_pr3={row['speedup_vs_pr3_committed']:.2f}x"))
            print(f"# psi n={n}: {row['round_ms']:.0f} ms "
                  f"({row['ids_per_s']:.0f} ids/s, "
                  f"{row['peak_rss_mb']:.0f} MB peak)", file=sys.stderr)
        if compare_n in sizes:
            # the acceptance comparison row: same size, serial engine +
            # the wire-compressed bloom variant, one-shot
            dt, _, _ = _one_round(compare_n, overlap, group,
                                  max(compare_n, 1), 0)
            traj[str(compare_n)]["serial_round_ms"] = 1e3 * dt
            traj[str(compare_n)]["speedup_vs_serial"] = (
                dt * 1e3 / traj[str(compare_n)]["round_ms"])
            rows.append((f"psi_serial_n{compare_n}", 1e6 * dt,
                         f"parallel_speedup="
                         f"{traj[str(compare_n)]['speedup_vs_serial']:.2f}x"
                         ))
            bdt, _, bstats = _one_round(compare_n, overlap, group,
                                        chunk_size, parallelism,
                                        mode="bloom")
            traj[str(compare_n)]["bloom_mode_round_ms"] = 1e3 * bdt
            traj[str(compare_n)]["bloom_mode_compression_ratio"] = (
                bstats["uncompressed_server_set_bytes"]
                / max(bstats["bloom_bytes"], 1))
            rows.append((f"psi_bloom_n{compare_n}", 1e6 * bdt,
                         f"compression="
                         f"{traj[str(compare_n)]['bloom_mode_compression_ratio']:.1f}x"))
        report["trajectory"] = traj

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    return rows


def run_check(out="BENCH_psi.json"):
    """The ``--check`` shape: gate section only (the trajectory is
    skipped by the checker)."""
    return run(trajectory=False, out=out)


def run_fast(out="BENCH_psi_fast.json"):
    """CI-sized smoke: small gate, tiny trajectory.  Writes to a
    scratch name by default — its gate sizes differ from the committed
    baseline's, so it must never clobber ``BENCH_psi.json`` (the
    bench-check exact-match rules could then never pass)."""
    return run(sizes=(1000, 4000), gate_n=1000, compare_n=4000,
               chunk_size=512, out=out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--one-size", type=int, default=None,
                    help="measure one trajectory row, print JSON (used "
                         "by the parent via subprocess for clean RSS)")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--overlap", type=float, default=0.5)
    ap.add_argument("--group", default="modp512")
    ap.add_argument("--chunk-size", type=int, default=DEFAULT_CHUNK)
    ap.add_argument("--parallelism", type=int, default=DEFAULT_PAR)
    args = ap.parse_args(argv)
    if args.one_size is not None:
        print(json.dumps(measure_size(
            args.one_size, args.overlap, args.group, args.chunk_size,
            args.parallelism)))
        return
    fn = run_fast if args.fast else run
    for r in fn():
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
