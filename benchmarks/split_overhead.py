"""Split-vs-monolithic training overhead: the framework-cost question a
deployer asks.  Trains the paper MLP both ways (identical math, claim C3)
and a reduced llama split model, reporting wall time per step.

Rows: (name, us_per_call=us per step, derived=loss after warmup).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.pyvertical_mnist import CONFIG as MNIST_CFG
from repro.core.splitnn import (MLPSplitNN, make_split_train_step,
                                train_state_init)
from repro.data import make_mnist_like, make_token_dataset
from repro.models.model import SplitModel
from repro.optim import adam, chain, clip_by_global_norm, multi_segment, sgd


def _bench_step(step, params, state, batch, iters=10):
    params, state, m = step(params, state, batch, 0)      # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for i in range(iters):
        params, state, m = step(params, state, batch, i)
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / iters, float(m["loss"])


def run():
    rows = []
    X, y = make_mnist_like(512, 0)
    xs = jnp.asarray(np.stack(np.split(X[:128], 2, axis=1)))
    batch = {"x_slices": xs, "labels": jnp.asarray(y[:128])}

    model = MLPSplitNN(MNIST_CFG)
    params = model.init(jax.random.PRNGKey(0))
    opt = multi_segment({"heads": sgd(0.01), "trunk": sgd(0.1)})
    step = make_split_train_step(model.loss_fn, opt, donate=False)
    dt, loss = _bench_step(step, params, train_state_init(params, opt),
                           batch)
    rows.append(("mlp_split_step", 1e6 * dt, round(loss, 4)))

    cfg = get_config("llama3.2-3b", reduced=True)
    m2 = SplitModel(cfg)
    p2 = m2.init(jax.random.PRNGKey(0))
    toks = make_token_dataset(8, 128, cfg.vocab, 0)
    b2 = {"owner_tokens": jnp.asarray(
        toks[:, :-1].reshape(8, 2, 64).transpose(1, 0, 2)),
        "labels": jnp.asarray(toks[:, 1:])}
    opt2 = multi_segment({
        "heads": chain(clip_by_global_norm(1.0), adam(1e-3)),
        "trunk": chain(clip_by_global_norm(1.0), adam(1e-3))})
    step2 = make_split_train_step(m2.loss_fn, opt2, donate=False)
    dt, loss = _bench_step(step2, p2, train_state_init(p2, opt2), b2,
                           iters=3)
    rows.append(("llama_reduced_split_step", 1e6 * dt, round(loss, 4)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
