"""Privacy trade-off benchmark (``BENCH_privacy.json``).

Three questions about the cut-layer privacy hardening:

  1. correctness — a masked-sum split fit must reproduce the masked
     joint oracle *bitwise* (``bit_identical``, exact-gated), and the
     ring-coded forward must cost exactly zero extra wire bytes over
     the plain f32 cut (``extra_cut_bytes``, exact-gated at 0);
  2. leakage — the transcript attacks (tests/attacks/harness.py) run
     against real captured traffic with defenses off and on; the gate
     pins the attacker's scores (abs-tolerance) and the boolean
     ``leakage_gap_positive`` = every defense strictly reduced its
     attacker's leakage (exact-gated at 1);
  3. cost — what masking and the gradient defenses cost in step time
     (ratio-gated) and final training accuracy (abs-gated).

Writes ``BENCH_privacy.json`` and returns the usual CSV rows.
"""
from __future__ import annotations

import dataclasses
import json
import os
import sys

import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.join(ROOT, "tests") not in sys.path:
    sys.path.insert(0, os.path.join(ROOT, "tests"))

from repro.configs.pyvertical_mnist import CONFIG
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties

#: committed-baseline gate geometry (matches the attack harness)
GATE_N, GATE_BATCH, GATE_STEPS = 256, 64, 6


def _fit(mode="split", aggregation=None, grad_norm_mode="none",
         grad_noise_std=0.0, cut_noise_std=0.0):
    import jax
    sci, raw = make_vertical_mnist_parties(GATE_N, seed=0,
                                           keep_frac=0.9)
    s = VerticalSession(*feature_parties(sci, raw))
    s.resolve(group="modp512")
    s.build(dataclasses.replace(CONFIG, split=dataclasses.replace(
        CONFIG.split, combine="sum", grad_norm_mode=grad_norm_mode,
        grad_noise_std=grad_noise_std, cut_noise_std=cut_noise_std)))
    kw = dict(steps=GATE_STEPS, batch_size=GATE_BATCH, verbose=False,
              aggregation=aggregation, mode=mode)
    if mode == "split":
        kw["backend"] = "queue"
    h = s.fit(**kw)
    ts = s.transport_stats if mode == "split" else {}
    return {
        "leaves": [np.asarray(x)
                   for x in jax.tree_util.tree_leaves(s.params)],
        "accuracy": float(h["train"][-1]["accuracy"]),
        "step_ms": ts.get("steady_step_ms", 0.0),
        "cut_bytes": sum(
            ts["per_owner"][o.name]["cut_payload_bytes"]
            for o in s.owners) if ts else 0,
    }


def run(out: str = "BENCH_privacy.json"):
    from attacks import harness as H

    report: dict = {"config": {"n": GATE_N, "batch": GATE_BATCH,
                               "steps": GATE_STEPS,
                               "combine": "sum", "backend": "queue"}}
    rows = []

    # -- 1. masked-sum correctness + overhead ------------------------------
    oracle = _fit(mode="joint", aggregation="masked_sum")
    masked = _fit(aggregation="masked_sum")
    plain = _fit()
    bit_identical = int(
        len(masked["leaves"]) == len(oracle["leaves"])
        and all(np.array_equal(a, b) for a, b in
                zip(masked["leaves"], oracle["leaves"])))
    masked_cell = {
        "bit_identical": bit_identical,
        "extra_cut_bytes": masked["cut_bytes"] - plain["cut_bytes"],
        "masking_step_overhead_ratio": (
            masked["step_ms"] / max(plain["step_ms"], 1e-9)),
        "masked_accuracy": masked["accuracy"],
        "plain_accuracy": plain["accuracy"],
    }
    report["masked"] = masked_cell
    rows.append(("privacy_masked_bit_identical", bit_identical,
                 f"extra_cut_bytes={masked_cell['extra_cut_bytes']}"))
    rows.append(("privacy_masking_overhead",
                 round(masked_cell["masking_step_overhead_ratio"], 3),
                 f"masked_step_ms={masked['step_ms']:.2f}"))

    # -- 2. accuracy cost of the gradient defenses -------------------------
    defended = _fit(grad_norm_mode="unit")
    report["defense_cost"] = {
        "grad_unit_accuracy": defended["accuracy"],
        "grad_unit_step_overhead_ratio": (
            defended["step_ms"] / max(plain["step_ms"], 1e-9)),
    }
    rows.append(("privacy_grad_unit_accuracy",
                 round(defended["accuracy"], 4),
                 f"plain={plain['accuracy']:.4f}"))

    # -- 3. transcript attacks: leakage before/after each defense ----------
    kw = dict(n=GATE_N, steps=GATE_STEPS, batch_size=GATE_BATCH)
    base = H.capture_transcript(**kw)
    t_noise = H.capture_transcript(cut_noise_std=2.0, **kw)
    t_mask = H.capture_transcript(aggregation="masked_sum", **kw)
    t_gnoise = H.capture_transcript(grad_noise_std=0.05, **kw)
    t_unit = H.capture_transcript(grad_norm_mode="unit", **kw)
    t_sign = H.capture_transcript(grad_norm_mode="sign", **kw)

    def fwd(tr, metric):
        return float(np.mean([metric(tr, o) for o in sorted(tr.cuts)]))

    attacks = {
        "baseline_inversion_r2": fwd(base, H.inversion_r2),
        "cut_noise_inversion_r2": fwd(t_noise, H.inversion_r2),
        "masked_inversion_r2": fwd(t_mask, H.inversion_r2),
        "baseline_dcor": fwd(base, H.dcor_leakage),
        "cut_noise_dcor": fwd(t_noise, H.dcor_leakage),
        "masked_dcor": fwd(t_mask, H.dcor_leakage),
        "baseline_norm_auc": H.norm_attack_auc(base),
        "grad_noise_norm_auc": H.norm_attack_auc(t_gnoise),
        "grad_unit_norm_auc": H.norm_attack_auc(t_unit),
        "grad_sign_norm_auc": H.norm_attack_auc(t_sign),
    }
    gaps = [
        attacks["baseline_inversion_r2"]
        - attacks["cut_noise_inversion_r2"],
        attacks["baseline_inversion_r2"]
        - attacks["masked_inversion_r2"],
        attacks["baseline_dcor"] - attacks["cut_noise_dcor"],
        attacks["baseline_dcor"] - attacks["masked_dcor"],
        attacks["baseline_norm_auc"] - attacks["grad_noise_norm_auc"],
        attacks["baseline_norm_auc"] - attacks["grad_unit_norm_auc"],
        attacks["baseline_norm_auc"] - attacks["grad_sign_norm_auc"],
    ]
    attacks["leakage_gap_positive"] = int(all(g > 0 for g in gaps))
    report["attacks"] = attacks
    rows.append(("privacy_leakage_gap_positive",
                 attacks["leakage_gap_positive"],
                 f"min_gap={min(gaps):+.4f}"))
    rows.append(("privacy_baseline_norm_auc",
                 round(attacks["baseline_norm_auc"], 4),
                 f"unit={attacks['grad_unit_norm_auc']:.4f}"))
    rows.append(("privacy_baseline_inversion_r2",
                 round(attacks["baseline_inversion_r2"], 4),
                 f"masked={attacks['masked_inversion_r2']:.4f}"))

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    return rows


def run_check(out: str = "BENCH_privacy.json"):
    return run(out)


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
