"""Roofline analysis over the dry-run artifacts (deliverable g).

Per (arch x shape x mesh), from the compiled dry-run JSON:

    compute term    = HLO_FLOPs_total / (chips * peak_FLOP/s)
    memory term     = HLO_bytes_total / (chips * HBM_bw)
    collective term = collective_bytes_per_dev / link_bw

cost_analysis() on the SPMD-partitioned module reports PER-DEVICE flops
and bytes (the module is the per-device program), so totals multiply by
the device count; collective bytes were parsed per-device already.

MODEL_FLOPS = 6 * N * D (dense) or 6 * N_active * D (MoE), D = tokens
processed in the step (x3 for the backward pass in training).

    PYTHONPATH=src python -m benchmarks.roofline [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.analytic import step_costs
from repro.configs import SHAPES, get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun")
HBM_PER_CHIP = 16 * 2 ** 30          # v5e: 16 GiB


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n * tokens
    # decode: one token per request
    return 2.0 * n * shape.global_batch


def terms(rec: dict) -> dict:
    """Three roofline terms from the ANALYTIC model (primary — see
    benchmarks/analytic.py for why XLA cost_analysis cannot be used
    directly for scanned models), plus HLO-reported values as relative
    reference metrics."""
    chips = rec["n_devices"]
    ac = step_costs(rec["arch"], rec["shape"])
    t_c = ac.flops / (chips * PEAK_FLOPS_BF16)
    t_m = ac.hbm_bytes / (chips * HBM_BW)
    t_x = ac.coll_bytes_dev / ICI_BW
    # HLO-reported (scan bodies counted once — relative metric only)
    hlo_c = rec["cost"]["flops"] * chips / (chips * PEAK_FLOPS_BF16)
    hlo_x = rec["collectives"]["total_bytes"] / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    mf = model_flops(rec["arch"], rec["shape"])
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom[0], "bound_s": dom[1],
        "model_flops": mf,
        "useful_frac": mf / max(ac.flops, 1.0),
        "hlo_compute_s": hlo_c, "hlo_collective_s": hlo_x,
        "hbm_gib": rec["hbm_per_device_bytes"] / 2 ** 30,
        "fits_hbm": rec["hbm_per_device_bytes"] <= HBM_PER_CHIP,
        "cross_pod_mib": rec["collectives"].get("cross_pod_bytes", 0) / 2**20,
    }


def load(art_dir: str = ART_DIR, mesh: str = None, tag: str = ""):
    out = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") != "ok":
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        # baseline artifacts are named exactly {arch}_{shape}_{mesh}.json;
        # hillclimb variants carry suffixes (_tdp, _mb16, _ring, ...)
        base = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        stem = os.path.basename(f)[:-len(".json")]
        if not tag and stem != base:
            continue
        if tag and not stem.endswith(f"_{tag}"):
            continue
        rec["_file"] = os.path.basename(f)
        out.append(rec)
    return out


def fmt_s(x):
    if x >= 1.0:
        return f"{x:7.2f}s"
    return f"{x*1e3:6.1f}ms"


def table(records, markdown=False):
    rows = []
    hdr = ["arch", "shape", "mesh", "compute", "memory", "collective",
           "dominant", "useful", "HBM/dev", "fits"]
    for rec in records:
        t = terms(rec)
        rows.append([
            rec["arch"], rec["shape"], rec["mesh"],
            fmt_s(t["compute_s"]), fmt_s(t["memory_s"]),
            fmt_s(t["collective_s"]), t["dominant"],
            f"{t['useful_frac']*100:5.1f}%",
            f"{t['hbm_gib']:8.2f}G", "y" if t["fits_hbm"] else "OVER",
        ])
    if markdown:
        lines = ["| " + " | ".join(hdr) + " |",
                 "|" + "|".join("---" for _ in hdr) + "|"]
        lines += ["| " + " | ".join(str(c) for c in r) + " |" for r in rows]
        return "\n".join(lines)
    w = [max(len(str(r[i])) for r in rows + [hdr]) for i in range(len(hdr))]
    lines = ["  ".join(h.ljust(w[i]) for i, h in enumerate(hdr))]
    lines += ["  ".join(str(c).ljust(w[i]) for i, c in enumerate(r))
              for r in rows]
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--dir", default=ART_DIR)
    args = ap.parse_args()
    recs = load(args.dir, args.mesh, args.tag)
    print(table(recs, markdown=args.markdown))


if __name__ == "__main__":
    main()
