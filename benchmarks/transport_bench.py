"""Transport-layer benchmark: what true split execution costs and what
the pipeline + microbatch + compression levers buy back.

Four questions, all answered with *measured* numbers off the transport
channels (never the analytic ``cut_layer_traffic`` estimate):

  1. overhead  — joint autodiff step vs split execution over the queue
     transport (per-step wall time; every compile is excluded by the
     session's warmup handshake);
  2. overlap   — sequential vs pipelined schedule under injected channel
     latency.  The pipelined schedule pre-stages the next forward
     request, ships cut gradients before the trunk update, and runs the
     trunk's weight gradients + optimizer inside the wire's round-trip
     window, so the per-step cost approaches the protocol's wire floor
     of ``2 x latency`` (one exact-SGD step cannot beat one round
     trip).  ``split_overhead_vs_lower_bound`` tracks how close it
     gets — the gap is host compute/dispatch that the schedule could
     not hide;
  3. depth     — a latency x microbatch-count sweep
     (``fit(microbatches=M)`` keeps M GPipe cut exchanges in flight per
     channel).  The headline pipelined number uses the sweep's best
     depth at the headline latency: chunking pays when per-chunk
     compute is large relative to program-dispatch overhead, so tiny
     models on small hosts typically pick M=1 while real accelerators
     favor deeper pipelines;
  4. bytes     — cut-layer payload bytes/step for none | fp16 | int8
     codecs, with the end-of-training val accuracy each reaches.

Writes ``BENCH_transport.json`` and returns the usual CSV rows
(name, us_per_call, derived).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs.pyvertical_mnist import CONFIG
from repro.core.splitnn import make_split_train_step, train_state_init
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties


def _session(n):
    sci, owners = make_vertical_mnist_parties(n, seed=0, keep_frac=0.9)
    s = VerticalSession(*feature_parties(sci, owners))
    s.resolve(group="modp512")
    s.build(CONFIG)
    return s


def _joint_step_ms(session, batch=128, iters=20):
    """Compile-free per-step wall time of the joint autodiff program."""
    adapter = session.adapter
    opt = adapter.default_optimizer(None, None)
    params = session.params
    state = train_state_init(params, opt)
    step = make_split_train_step(adapter.loss_fn, opt, donate=False)
    arrays = [o._features for o in session.owners]
    b = adapter.make_batch(arrays, session.scientist.labels,
                           np.arange(batch))
    params, state, m = step(params, state, b, 0)          # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for i in range(iters):
        params, state, m = step(params, state, b, i)
    jax.block_until_ready(m["loss"])
    return 1e3 * (time.perf_counter() - t0) / iters


def _split_ms(n, batch, *, schedule, micro=1, latency_s=0.0, trials=1):
    vals = []
    for _ in range(trials):
        s = _session(n)
        s.fit(epochs=2, batch_size=batch, verbose=False, mode="split",
              schedule=schedule, microbatches=micro, latency_s=latency_s)
        vals.append(s.transport_stats["steady_step_ms"])
    return float(np.median(vals))


def run(n=1500, epochs=6, batch=128, latency_ms=8.0,
        trials=3, sweep=True, out="BENCH_transport.json"):
    report: dict = {"config": {"n": n, "epochs": epochs, "batch": batch,
                               "latency_ms": latency_ms}}
    rows = []

    joint_ms = _joint_step_ms(_session(n), batch)
    report["joint_step_ms"] = joint_ms
    rows.append(("transport_joint_step", round(1e3 * joint_ms, 1), ""))

    lat = latency_ms * 1e-3

    # ---- depth: pick the pipelined schedule's microbatch count at the
    # headline latency (one probe per depth)
    micro_grid = (1, 2, 4) if sweep else (1, 2)
    head_cells = {str(m): _split_ms(n, batch, schedule="pipelined",
                                    micro=m, latency_s=lat)
                  for m in micro_grid}
    best_micro = int(min(head_cells, key=lambda k: head_cells[k]))
    report["pipelined_microbatches"] = best_micro

    # ---- overlap: sequential vs pipelined (best depth) at the headline
    # latency.  The box's throughput drifts ~25% on minute scales, so
    # the schedules are measured in interleaved PAIRS and the speedup is
    # the median of per-pair ratios (both sides of each ratio see the
    # same phase).  Measured BEFORE the big sweep: tens of accumulated
    # in-process sessions measurably slow later fits on a small host.
    seq_trials, pipe_trials = [], []
    for _ in range(trials):
        seq_trials.append(_split_ms(n, batch, schedule="sequential",
                                    latency_s=lat))
        pipe_trials.append(_split_ms(n, batch, schedule="pipelined",
                                     micro=best_micro, latency_s=lat))
    seq_ms = float(np.median(seq_trials))
    pipe_ms = float(np.median(pipe_trials))
    report["split_sequential_step_ms"] = seq_ms
    report["split_pipelined_step_ms"] = pipe_ms
    report["pipeline_speedup"] = float(np.median(
        [s / max(p, 1e-9) for s, p in zip(seq_trials, pipe_trials)]))
    # the wire floor of one exact-SGD step: a full round trip.  The
    # sequential baseline's floor is two (fwd request + cut, grads +
    # ack).  Everything above the floor is host-side.
    report["lower_bound_ms"] = 2.0 * latency_ms
    report["split_overhead_vs_lower_bound"] = (
        pipe_ms / max(2.0 * latency_ms, 1e-9) if latency_ms else None)
    rows.append(("transport_split_sequential_step",
                 round(1e3 * seq_ms, 1), f"lat={latency_ms}ms"))
    xlb = report["split_overhead_vs_lower_bound"]
    rows.append(("transport_split_pipelined_step",
                 round(1e3 * pipe_ms, 1),
                 f"lat={latency_ms}ms M={best_micro}"
                 + (f" x_lower_bound={xlb:.2f}" if xlb else "")))

    # ---- the full latency x depth sweep (informational)
    if sweep:
        sweep_tab = {str(latency_ms): head_cells}
        for lms in sorted({0.0, latency_ms / 4}):
            sweep_tab[str(lms)] = {
                str(m): _split_ms(n, batch, schedule="pipelined", micro=m,
                                  latency_s=lms * 1e-3)
                for m in micro_grid}
        report["pipeline_sweep"] = sweep_tab

    # ---- bytes: codec sweep, measured payload bytes + final accuracy
    report["compression"] = {}
    base_bytes = None
    for codec in ("none", "fp16", "int8"):
        s = _session(n)
        h = s.fit(epochs=epochs, batch_size=batch, eval_frac=0.15,
                  verbose=False, mode="split",
                  compression=None if codec == "none" else codec)
        ts = s.transport_stats
        acc = h["final"]["val_accuracy"]
        entry = {
            "cut_payload_bytes_per_step": ts["cut_payload_bytes_per_step"],
            "total_payload_bytes_per_step":
                ts["total_payload_bytes_per_step"],
            "total_wire_bytes": ts["total_wire_bytes"],
            "val_accuracy": acc,
        }
        if codec == "none":
            base_bytes = ts["total_payload_bytes_per_step"]
            report["uncompressed_val_accuracy"] = acc
        entry["compression_ratio"] = (base_bytes
                                      / ts["total_payload_bytes_per_step"])
        report["compression"][codec] = entry
        rows.append((f"transport_bytes_{codec}",
                     ts["total_payload_bytes_per_step"],
                     f"val_acc={acc:.3f}"))

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
