"""Transport-layer benchmark: what true split execution costs and what
the pipeline + compression levers buy back.

Three questions, all answered with *measured* numbers off the transport
channels (never the analytic ``cut_layer_traffic`` estimate):

  1. overhead  — joint autodiff step vs split execution over the queue
     transport (per-step wall time, compile excluded);
  2. overlap   — sequential vs pipelined schedule under injected channel
     latency (the pipelined schedule hides the grad/fwd round-trip and
     the owners' compute behind the scientist's trunk update).  The
     default ``latency_ms`` models a LAN-ish one-way delay: pipelining
     pays off when transit time dominates — on a tiny shared-CPU box
     with zero latency the overlapped compute just contends for cores;
  3. bytes     — cut-layer payload bytes/step for none | fp16 | int8
     codecs, with the end-of-training val accuracy each reaches.

Writes ``BENCH_transport.json`` and returns the usual CSV rows
(name, us_per_call, derived).
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs.pyvertical_mnist import CONFIG
from repro.core.splitnn import make_split_train_step, train_state_init
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties


def _session(n):
    sci, owners = make_vertical_mnist_parties(n, seed=0, keep_frac=0.9)
    s = VerticalSession(*feature_parties(sci, owners))
    s.resolve(group="modp512")
    s.build(CONFIG)
    return s


def _joint_step_ms(session, batch=128, iters=20):
    """Compile-free per-step wall time of the joint autodiff program."""
    adapter = session.adapter
    opt = adapter.default_optimizer(None, None)
    params = session.params
    state = train_state_init(params, opt)
    step = make_split_train_step(adapter.loss_fn, opt, donate=False)
    arrays = [o._features for o in session.owners]
    b = adapter.make_batch(arrays, session.scientist.labels,
                           np.arange(batch))
    params, state, m = step(params, state, b, 0)          # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for i in range(iters):
        params, state, m = step(params, state, b, i)
    jax.block_until_ready(m["loss"])
    return 1e3 * (time.perf_counter() - t0) / iters


def run(n=1500, epochs=6, batch=128, latency_ms=8.0,
        out="BENCH_transport.json"):
    report: dict = {"config": {"n": n, "epochs": epochs, "batch": batch,
                               "latency_ms": latency_ms}}
    rows = []

    joint_ms = _joint_step_ms(_session(n), batch)
    report["joint_step_ms"] = joint_ms
    rows.append(("transport_joint_step", round(1e3 * joint_ms, 1), ""))

    # ---- overlap: sequential vs pipelined under injected latency
    # (median of 3 trials — the shared-CPU box is noisy)
    lat = latency_ms * 1e-3
    sched_ms = {}
    for sched in ("sequential", "pipelined"):
        trials = []
        for _ in range(3):
            s = _session(n)
            s.fit(epochs=2, batch_size=batch, verbose=False, mode="split",
                  schedule=sched, latency_s=lat)
            trials.append(s.transport_stats["steady_step_ms"])
        sched_ms[sched] = float(np.median(trials))
        rows.append((f"transport_split_{sched}_step",
                     round(1e3 * sched_ms[sched], 1), f"lat={latency_ms}ms"))
    report["split_sequential_step_ms"] = sched_ms["sequential"]
    report["split_pipelined_step_ms"] = sched_ms["pipelined"]
    report["pipeline_speedup"] = (sched_ms["sequential"]
                                  / max(sched_ms["pipelined"], 1e-9))

    # ---- bytes: codec sweep, measured payload bytes + final accuracy
    report["compression"] = {}
    base_bytes = None
    for codec in ("none", "fp16", "int8"):
        s = _session(n)
        h = s.fit(epochs=epochs, batch_size=batch, eval_frac=0.15,
                  verbose=False, mode="split",
                  compression=None if codec == "none" else codec)
        ts = s.transport_stats
        acc = h["final"]["val_accuracy"]
        entry = {
            "cut_payload_bytes_per_step": ts["cut_payload_bytes_per_step"],
            "total_payload_bytes_per_step":
                ts["total_payload_bytes_per_step"],
            "total_wire_bytes": ts["total_wire_bytes"],
            "val_accuracy": acc,
        }
        if codec == "none":
            base_bytes = ts["total_payload_bytes_per_step"]
            report["uncompressed_val_accuracy"] = acc
        entry["compression_ratio"] = (base_bytes
                                      / ts["total_payload_bytes_per_step"])
        report["compression"][codec] = entry
        rows.append((f"transport_bytes_{codec}",
                     ts["total_payload_bytes_per_step"],
                     f"val_acc={acc:.3f}"))

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
