"""Continuous-batching serving benchmark (``BENCH_serving.json``).

Three questions about the serving engine (``launch/engine.py``):

  1. **continuous vs wave** — a paired, interleaved A/B of the *same*
     mixed-length request set (one long request per 4-slot wave, the
     rest short) through both schedulers over the queue transport at
     8 ms injected wire latency.  Wave batching pays the slowest
     request's ticks for every wave; continuous batching refills freed
     slots immediately and overlaps the refill's prefill ship with the
     decode ship's latency window, so sustained requests/s must beat
     wave by >= 1.3x (the gate re-asserts the floor on every
     ``make bench-check`` run — min-of-``pairs`` walls on each side,
     so the box's scheduling noise cancels).
  2. **repeat-entity cut cache** — a returning entity's request must
     ship *zero* cut-upload bytes and recompute nothing owner-side
     (transcript-asserted cache hit; exact-gated byte metric).
  3. **bit-identity** — both schedulers generate identical greedy
     tokens for the gate's request set (exact-gated flag).

The informational ``serving_sweep`` subtree (committed by full runs,
skipped under ``--check``) crosses injected latency (0/2/8 ms) x cut
compression (none/fp16/int8) x transport backend (direct/queue/process)
and records sustained req/s + honest per-request p50/p99 latency.
Compiles land outside every timed region (a warmup drain first).
"""
from __future__ import annotations

import json
import time

import numpy as np

#: committed-baseline gate geometry: 4 slots, 8 requests, one long
#: request per wave-of-4 (the wave scheduler's worst honest case)
GATE_B, GATE_CTX, GATE_N = 4, 32, 8
GATE_MAX_NEW = 12
GATE_MIX = (12, 1, 1, 1, 12, 1, 1, 1)
GATE_LATENCY_S = 0.008
SPEEDUP_FLOOR = 1.3

SWEEP_LATENCIES_MS = (0, 2, 8)
SWEEP_COMPRESSIONS = (None, "fp16", "int8")
SWEEP_BACKENDS = ("direct", "queue", "process")


def _build():
    import jax
    from repro.configs import get_config
    from repro.models.model import SplitModel
    cfg = get_config("llama3.2-3b", reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _contexts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, GATE_CTX) for _ in range(n)]


def _serve(model, params, ctxs, mix, *, scheduler, transport="queue",
           latency_s=0.0, compression=None, cut_cache=None,
           batch_slots=GATE_B):
    """One timed drain: warmup (compiles) then the measured run.
    Returns (wall_s, {rid: generated}, latencies_s, engine)."""
    from repro.launch.engine import ServingEngine
    eng = ServingEngine(model, params, batch_slots=batch_slots,
                        ctx_len=GATE_CTX, max_new=GATE_MAX_NEW,
                        scheduler=scheduler, transport=transport,
                        latency_s=latency_s, compression=compression,
                        cut_cache=cut_cache)
    for c in ctxs[:batch_slots]:             # warmup: prefill + decode
        eng.submit(c, max_new=2)             # programs compile here
    eng.run()
    t0 = time.perf_counter()
    rids = [eng.submit(c, max_new=m) for c, m in zip(ctxs, mix)]
    out = eng.run()
    wall = time.perf_counter() - t0
    gens = {r: out[r].generated for r in rids}
    lats = [out[r].latency_s for r in rids]
    return wall, gens, lats, eng


def _gate(model, params, cfg, pairs: int):
    """Paired interleaved A/B (wave, continuous, wave, ...) + the
    repeat-entity and bit-identity sections, at the committed size."""
    ctxs = _contexts(cfg, GATE_N)
    w_walls, c_walls = [], []
    w_gens = c_gens = None
    c_lats = None
    for _ in range(pairs):
        w, w_gens, _, ew = _serve(model, params, ctxs, GATE_MIX,
                                  scheduler="wave",
                                  latency_s=GATE_LATENCY_S)
        ew.close()
        c, c_gens, c_lats, ec = _serve(model, params, ctxs, GATE_MIX,
                                       scheduler="continuous",
                                       latency_s=GATE_LATENCY_S)
        refills = ec.stats["slot_refills"]
        ec.close()
        w_walls.append(w)
        c_walls.append(c)
    wave_wall, cont_wall = min(w_walls), min(c_walls)
    speedup = wave_wall / max(cont_wall, 1e-9)
    identical = int(w_gens == c_gens)

    # repeat entity: second visit ships zero cut-upload bytes and
    # recomputes no head prefill (one admission control frame only)
    from repro.launch.engine import ServingEngine
    eng = ServingEngine(model, params, batch_slots=GATE_B,
                        ctx_len=GATE_CTX, max_new=GATE_MAX_NEW,
                        scheduler="continuous", transport="queue",
                        cut_cache=True)
    eng.submit(ctxs[0], max_new=4)
    first = eng.run()
    pb, pc = eng.stats["cut_payload_bytes"], eng.stats["prefill_calls"]
    rid2 = eng.submit(ctxs[0], max_new=1)
    second = eng.run()
    repeat_bytes = eng.stats["cut_payload_bytes"] - pb
    repeat_prefills = eng.stats["prefill_calls"] - pc
    hit = int(any(e[0] == "cut_cache_hit" and e[1] == rid2
                  for e in eng.transcript))
    tok_match = int(second[rid2].generated[0]
                    == first[min(first)].generated[0])
    eng.close()

    gate = {
        "wave_wall_ms": 1e3 * wave_wall,
        "continuous_wall_ms": 1e3 * cont_wall,
        "continuous_vs_wave_speedup": speedup,
        "meets_1p3_floor": int(speedup >= SPEEDUP_FLOOR),
        "continuous_req_per_s": GATE_N / max(cont_wall, 1e-9),
        "wave_req_per_s": GATE_N / max(wave_wall, 1e-9),
        "p50_latency_ms": 1e3 * float(np.percentile(c_lats, 50)),
        "p99_latency_ms": 1e3 * float(np.percentile(c_lats, 99)),
        "slot_refills": refills,
        "bit_identical": identical,
        "repeat_cut_upload_bytes": repeat_bytes,
        "repeat_head_prefills": repeat_prefills,
        "cut_cache_hits": hit,
        "repeat_token_bitwise": tok_match,
    }
    failures = []
    if speedup < SPEEDUP_FLOOR:
        failures.append(f"continuous/wave speedup {speedup:.2f} < "
                        f"{SPEEDUP_FLOOR} at 8 ms injected latency")
    if not identical:
        failures.append("wave and continuous generations differ")
    if repeat_bytes != 0 or repeat_prefills != 0 or not hit:
        failures.append(
            f"repeat entity not free: bytes={repeat_bytes} "
            f"prefills={repeat_prefills} transcript_hit={hit}")
    if failures:
        raise RuntimeError("serving gate failed: " + "; ".join(failures))
    return gate


def _sweep(model, params, cfg):
    """latency x compression x backend cross, continuous scheduler.
    Informational (host-dependent walls; committed by full runs)."""
    ctxs = _contexts(cfg, GATE_N, seed=1)
    tree: dict = {}
    rows = []
    for lat_ms in SWEEP_LATENCIES_MS:
        for comp in SWEEP_COMPRESSIONS:
            for backend in SWEEP_BACKENDS:
                wall, _, lats, eng = _serve(
                    model, params, ctxs, GATE_MIX,
                    scheduler="continuous", transport=backend,
                    latency_s=lat_ms * 1e-3, compression=comp)
                cell = {
                    "req_per_s": GATE_N / max(wall, 1e-9),
                    "p50_latency_ms": 1e3 * float(np.percentile(lats, 50)),
                    "p99_latency_ms": 1e3 * float(np.percentile(lats, 99)),
                    "cut_wire_bytes": eng.stats["cut_wire_bytes"],
                }
                eng.close()
                key = f"{lat_ms}ms_{comp or 'none'}_{backend}"
                tree[key] = cell
                rows.append((f"serving_{key}",
                             round(1e3 * wall, 1),
                             f"req/s={cell['req_per_s']:.1f}"))
    return tree, rows


def run(out: str = "BENCH_serving.json", *, sweep: bool = True,
        pairs: int = 3):
    cfg, model, params = _build()
    report: dict = {"config": {
        "batch_slots": GATE_B, "ctx_len": GATE_CTX, "n_requests": GATE_N,
        "max_new_mix": list(GATE_MIX), "latency_ms": 1e3 * GATE_LATENCY_S,
        "pairs": pairs, "arch": "llama3.2-3b (reduced)"}}
    rows = []

    gate = _gate(model, params, cfg, pairs)
    report["gate"] = gate
    rows.append(("serving_gate_wave_wall",
                 round(gate["wave_wall_ms"] * 1e3, 1),
                 f"req/s={gate['wave_req_per_s']:.1f}"))
    rows.append(("serving_gate_continuous_wall",
                 round(gate["continuous_wall_ms"] * 1e3, 1),
                 f"req/s={gate['continuous_req_per_s']:.1f} "
                 f"speedup={gate['continuous_vs_wave_speedup']:.2f}"))
    rows.append(("serving_gate_repeat_upload",
                 gate["repeat_cut_upload_bytes"],
                 f"cache_hit={gate['cut_cache_hits']} "
                 f"bit_identical={gate['bit_identical']}"))

    if sweep:
        report["serving_sweep"], srows = _sweep(model, params, cfg)
        rows.extend(srows)

    with open(out, "w") as f:
        json.dump(report, f, indent=2)
    return rows


def run_fast(out: str = "BENCH_serving.json"):
    return run(out, sweep=False, pairs=1)


def run_check(out: str = "BENCH_serving.json"):
    """The bench-check section: gate geometry only, no sweep — the
    1.3x floor, bit-identity, and the free repeat entity are
    re-asserted (hard failures), then compared against the committed
    baseline with the usual tolerances."""
    return run(out, sweep=False, pairs=3)


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
