"""Bench regression gate: compare freshly-measured BENCH_*.json reports
against the committed baselines with per-metric tolerances.

Usage (via the harness): ``python -m benchmarks.run --check`` or
``make bench-check``.  Fresh results are written to a temp directory and
never overwrite the committed baselines; the gate fails (exit 1) when a
tracked metric regresses beyond its tolerance or disappears.

Tolerance model — keyed on metric name, not location, so new report
sections inherit sane rules:

  * timings (``*_ms``, ``us_per_call``, ``ids_per_s``) — ratio bound
    (the shared-CPU box is noisy; 2.5x either way still catches the
    pathological regressions this gate exists for: compile landing in
    the timed region, a lost overlap, an accidental sync);
  * ratios (``*speedup*``, ``compression_ratio``, ``*_vs_lower_bound``,
    ``*amortization*``) — tighter ratio bound;
  * accuracies — absolute bound;
  * byte counts — exact (protocol traffic is deterministic);
  * ``config``/sweep tables and platform-dependent picks
    (``pipelined_microbatches``) — informational, skipped.
"""
from __future__ import annotations

import json
import math
import os
from typing import Iterator, Tuple

#: baseline file -> suite that regenerates it (benchmarks.run name)
TRACKED = {
    "BENCH_transport.json": "transport",
    "BENCH_psi.json": "psi_scaling",
    "BENCH_parties.json": "parties",
    "BENCH_serving.json": "serving",
    "BENCH_recovery.json": "recovery",
    "BENCH_privacy.json": "privacy",
}

#: informational subtrees: committed by full-size runs, not re-measured
#: under --check (the PSI trajectory's 1e6-ID row costs minutes; the
#: parties owners-sweep spawns dozens of workers, and its
#: ``informational`` subtree records host-dependent facts like core
#: count and the single-core speedup)
SKIP_SUBTREES = ("config", "pipeline_sweep", "trajectory", "wire_sweep",
                 "owners_sweep", "informational", "serving_sweep")
SKIP_KEYS = ("pipelined_microbatches",)


def _rule(key: str):
    """(kind, bound) tolerance for a metric name."""
    if key in SKIP_KEYS:
        return ("skip", None)
    if "accuracy" in key:
        return ("abs", 0.08)
    if key in ("n", "bloom_shards", "n_chunks", "chunk_size",
               "parallelism", "peak_inflight_elements",
               "bit_identical", "cut_cache_hits", "slot_refills",
               "repeat_head_prefills", "repeat_token_bitwise",
               "meets_1p3_floor", "n_recoveries",
               "leakage_gap_positive", "churn", "full_modexp_ops",
               "delta_modexp_ops"):
        return ("exact", None)      # deterministic protocol structure
    # attacker leakage scores: deterministic runs, but float-op order
    # may drift across platforms — absolute bands well inside the
    # defended-vs-baseline gaps the gate exists to preserve
    if key.endswith("_auc") or key.endswith("_dcor"):
        return ("abs", 0.1)
    if key.endswith("_r2"):
        return ("abs", 0.3)
    if "bytes" in key:
        return ("exact", None)
    if "peak" in key and key.endswith("_mb"):
        return ("ratio", 2.5)       # RSS drifts with allocator behavior
    if ("speedup" in key or "compression_ratio" in key
            or "amortization" in key or "vs_lower_bound" in key):
        return ("ratio", 2.0)
    if key == "lower_bound_ms":
        return ("exact", None)
    if (key.endswith("_ms") or key == "us_per_call"
            or key == "ids_per_s" or key == "wall_s"):
        return ("ratio", 2.5)
    return ("ratio", 2.5)   # default: treat unknown numerics as timings


def _leaves(tree, prefix="") -> Iterator[Tuple[str, str, float]]:
    """Yield (path, leaf_key, value) for every numeric leaf."""
    for k, v in tree.items():
        path = f"{prefix}.{k}" if prefix else k
        if k in SKIP_SUBTREES:
            continue
        if isinstance(v, dict):
            yield from _leaves(v, path)
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            yield path, k, float(v)


def compare(baseline: dict, fresh: dict, name: str = "") -> list:
    """Return a list of failure strings (empty = pass)."""
    fails = []
    fresh_flat = {p: v for p, _, v in _leaves(fresh)}
    for path, key, base in _leaves(baseline):
        kind, bound = _rule(key)
        if kind == "skip":
            continue
        if path not in fresh_flat:
            fails.append(f"{name}:{path}: missing from fresh results")
            continue
        new = fresh_flat[path]
        if kind == "exact":
            ok = new == base
            detail = f"{new} != {base}"
        elif kind == "abs":
            ok = abs(new - base) <= bound
            detail = f"|{new:.4f} - {base:.4f}| > {bound}"
        else:  # ratio
            if base == 0 or new == 0:
                ok = new == base
                detail = f"{new} vs {base} (zero)"
            else:
                r = new / base
                ok = 1.0 / bound <= r <= bound and math.isfinite(r)
                detail = f"{new:.4g} vs {base:.4g} (ratio {r:.2f} " \
                         f"outside [{1/bound:.2f}, {bound}])"
        if not ok:
            fails.append(f"{name}:{path}: {detail}")
    return fails


def check(repo_root: str = ".", fresh_dir: str = ".") -> int:
    """Compare every tracked baseline in ``repo_root`` against the same
    file in ``fresh_dir``.  Prints a PASS/FAIL line per file, returns
    the number of failures."""
    n_fail = 0
    for fname in TRACKED:
        base_path = os.path.join(repo_root, fname)
        fresh_path = os.path.join(fresh_dir, fname)
        if not os.path.exists(base_path):
            print(f"bench-check SKIP {fname} (no committed baseline)")
            continue
        if not os.path.exists(fresh_path):
            print(f"bench-check FAIL {fname} (fresh run produced no file)")
            n_fail += 1
            continue
        with open(base_path) as f:
            baseline = json.load(f)
        with open(fresh_path) as f:
            fresh = json.load(f)
        fails = compare(baseline, fresh, fname)
        if fails:
            n_fail += len(fails)
            print(f"bench-check FAIL {fname}:")
            for msg in fails:
                print(f"  {msg}")
        else:
            print(f"bench-check PASS {fname}")
    return n_fail
