"""Kernel-layer micro-benchmarks.

This host is CPU-only, so what executes is the jnp oracle path (the same
code the models run); the Pallas kernels are correctness-validated in
interpret mode and TARGET TPU.  We report the oracle's wall time (the
CPU substrate the tests/examples actually pay for) and, as `derived`,
the achieved GFLOP/s.

Rows: (name, us_per_call, derived=GFLOP/s).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.block_attention.ref import attention_ref
from repro.kernels.cut_fusion.ref import cut_fusion_ref
from repro.kernels.mamba2_scan.ref import ssd_ref


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    rng = np.random.default_rng(0)
    rows = []

    B, S, nh, nkv, hd = 2, 1024, 8, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, nh, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, nkv, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, nkv, hd)), jnp.float32)
    f = jax.jit(lambda q, k, v: attention_ref(q, k, v))
    dt = _time(f, q, k, v)
    flops = 4 * B * nh * S * S * hd
    rows.append(("attention_oracle_1k", 1e6 * dt,
                 round(flops / dt / 1e9, 1)))

    P, T, K, D = 2, 4096, 512, 1024
    z = jnp.asarray(rng.normal(size=(P, T, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(P, K, D)), jnp.float32)
    f = jax.jit(lambda z, w: cut_fusion_ref(z, w))
    dt = _time(f, z, w)
    flops = 2 * P * T * K * D
    rows.append(("cut_fusion_oracle_4k", 1e6 * dt,
                 round(flops / dt / 1e9, 1)))

    B, S, H, Pd, G, N = 1, 2048, 8, 64, 1, 64
    x = jnp.asarray(rng.normal(size=(B, S, H, Pd)), jnp.float32)
    dts = jnp.asarray(rng.uniform(0.001, 0.1, (B, S, H)), jnp.float32)
    A = -jnp.ones((H,), jnp.float32)
    Bi = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    Ci = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    f = jax.jit(lambda *a: ssd_ref(*a)[0])
    dt = _time(f, x, dts, A, Bi, Ci)
    chunk = 128
    flops = 2 * B * S * H * (chunk * N + chunk * Pd + N * Pd) * 2
    rows.append(("mamba2_ssd_oracle_2k", 1e6 * dt,
                 round(flops / dt / 1e9, 1)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
