"""Analytic FLOP / HBM-byte / collective-byte model per (arch x shape).

WHY THIS EXISTS: XLA's ``cost_analysis()`` counts each ``while``-loop body
ONCE, not x trip-count (verified in this container: a scan of length 1,
10 and 50 over a 512x512 matmul all report 268.7 MFLOP, while the
unrolled x10 version reports 2.687 GFLOP).  Our models are
scan-over-superblocks by design (HLO size independent of depth), so HLO
flops/bytes under-count by ~n_superblocks and inner-scan factors.
``memory_analysis()`` (buffer assignment) is NOT affected.

The roofline therefore uses this napkin model — every formula spelled out
below — as the primary source for the compute/memory/collective terms;
the HLO-reported values are kept in the artifacts as *relative* metrics
(same under-count before/after a change) and the discrepancy is
documented in EXPERIMENTS.md.

All quantities are WHOLE-STEP totals across the mesh; the roofline
divides by (chips x peak).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs import SHAPES, get_config
from repro.configs.base import ArchConfig, ShapeConfig


@dataclass
class Costs:
    flops: float            # total FLOPs for the step
    hbm_bytes: float        # total HBM traffic
    coll_bytes_dev: float   # collective bytes landing on ONE device
    notes: str = ""


def _layer_kinds(cfg: ArchConfig):
    """(kind, is_attn, window) per layer of the full network."""
    if cfg.enc_dec:
        return ([("attn:bidir", True, 0)] * cfg.n_enc_layers
                + [("dec", True, 0)] * cfg.n_layers)
    return [(k, k.startswith("attn") or k == "shared_attn",
             cfg.swa_window if k == "attn:local" else 0)
            for k in cfg.block_pattern] * cfg.n_superblocks


def fwd_flops(cfg: ArchConfig, shape: ShapeConfig, swa_override=0) -> float:
    """One forward pass over the step's tokens.

    matmul term: 2 * N_active * tokens  (the 6ND convention's forward).
    attention:   4 * B * nh * hd * S * ctx_avg per attn layer
                 (QK^T + PV, causal avg context = min(window, S)/2-ish).
    ssm/mlstm:   ~6 * d_inner * d_state per token per recurrent layer.
    """
    B, S = shape.global_batch, shape.seq_len
    P = cfg.split.n_owners
    decode = shape.kind == "decode"
    tokens = B * (1 if decode else S)
    n_active = cfg.param_count(active_only=True)
    total = 2.0 * n_active * tokens

    cut = min(max(cfg.split.cut_layer, 1), max(cfg.n_superblocks - 1, 1)) \
        if not cfg.enc_dec else cfg.n_enc_layers
    pat = len(cfg.block_pattern) if not cfg.enc_dec else 1
    n_head_layers = cut * pat

    for li, (kind, is_attn, window) in enumerate(_layer_kinds(cfg)):
        in_head = li < n_head_layers
        if swa_override and window == 0 and is_attn:
            window = swa_override
        if is_attn:
            if decode:
                ctx = min(window, S) if window else S
                # head layers see only the generation-owner slice
                if in_head:
                    ctx = min(ctx, S // P)
                total += 4.0 * B * cfg.n_heads * cfg.head_dim * ctx
            else:
                span = S // P if in_head else S
                ctx_avg = min(window, span) / 2 if window else span / 2
                total += 4.0 * B * cfg.n_heads * cfg.head_dim * S * ctx_avg \
                    / (1 if not in_head else 1)
        elif kind == "mamba2" and cfg.ssm:
            d_in = cfg.ssm.expand * cfg.d_model
            total += 6.0 * d_in * cfg.ssm.d_state * tokens
        elif kind == "mlstm" and cfg.xlstm:
            d_in = int(cfg.xlstm.m_proj_factor * cfg.d_model)
            total += 6.0 * d_in * (d_in // cfg.n_heads) * tokens
    return total


def step_costs(arch: str, shape_name: str, mesh_devices: int = 256,
               data_axis: int = 16, model_axis: int = 16,
               swa: bool = False) -> Costs:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    P = cfg.split.n_owners
    N = cfg.param_count(active_only=True)
    N_total = cfg.param_count(active_only=False)
    swa_w = cfg.long_context_window if (swa or (
        shape.name == "long_500k" and cfg.long_context == "swa")) else 0
    f_fwd = fwd_flops(cfg, shape, swa_override=swa_w)
    tokens = B * (1 if shape.kind == "decode" else S)
    d = cfg.d_model
    layers = cfg.n_layers + (cfg.n_enc_layers if cfg.enc_dec else 0)
    attn_layers = sum(1 for _, a, _ in _layer_kinds(cfg) if a)
    kv_bytes_tok = cfg.kv_dim * 2 * 2          # k+v, bf16

    if shape.kind == "train":
        flops = 4.0 * f_fwd                     # fwd + bwd(2x) + remat(1x)
        # params: fwd read + recompute read + grad w/r + adam m,v r/w +
        # param write, fp32
        p_traffic = N_total * 4.0 * 9
        act = layers * tokens * d * 2.0 * 6     # residual+internals, bf16
        hbm = p_traffic + act
        # collectives per device: TP all-reduce 4x/attn-layer of the
        # per-device activation slab (ring ~2x payload), + grad
        # all-reduce over data, + the cut-layer gather
        slab = (B / data_axis) * S * d * 2
        coll = attn_layers * 4 * 2 * slab
        coll += 2 * (N_total / model_axis) * 4
        coll += (B / data_axis) * S * d * 2     # cut activations
        if cfg.moe:
            coll += 2 * (tokens / data_axis) * cfg.moe.top_k * d * 2
    elif shape.kind == "prefill":
        flops = f_fwd
        hbm = N_total * 4.0 + layers * tokens * d * 2.0 * 2 \
            + attn_layers * tokens * kv_bytes_tok
        slab = (B / data_axis) * S * d * 2
        coll = attn_layers * 2 * 2 * slab + slab
        if cfg.moe:
            coll += 2 * (tokens / data_axis) * cfg.moe.top_k * d * 2
    else:  # decode: one token, full cache read
        flops = f_fwd
        ctx = min(swa_w, S) if swa_w else S
        cache_read = attn_layers * B * ctx * kv_bytes_tok
        if cfg.ssm:
            d_in = cfg.ssm.expand * d
            n_ssm = sum(1 for k, a, _ in _layer_kinds(cfg)
                        if k == "mamba2")
            cache_read += n_ssm * B * (d_in // cfg.ssm.head_dim) \
                * cfg.ssm.d_state * cfg.ssm.head_dim * 4 * 2
        hbm = N_total * 4.0 + cache_read + layers * B * d * 2.0 * 2
        coll = attn_layers * 2 * (B / max(min(B, data_axis), 1)) * d * 2
    return Costs(flops=flops, hbm_bytes=hbm, coll_bytes_dev=coll)
