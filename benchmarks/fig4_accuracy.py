"""Paper Figure 4: train/validation accuracy of the (unoptimised)
dual-headed SplitNN on vertically-partitioned MNIST-like data, plus the
centralized baseline (same topology, single party, single optimizer) the
paper implicitly compares against.

Returns rows: (name, us_per_call=us per train step, derived=val accuracy).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.pyvertical_mnist import CONFIG
from repro.core.splitnn import (MLPSplitNN, make_split_train_step,
                                train_state_init)
from repro.data import make_mnist_like
from repro.optim import multi_segment, sgd


def run(n=6000, epochs=30, seed=0):   # paper: 20k imgs, 30 epochs
    X, y = make_mnist_like(n, seed)
    ntr = int(n * 0.85)
    xs = np.stack(np.split(X, 2, axis=1))         # (P, N, 392)

    model = MLPSplitNN(CONFIG)
    rows = []

    def train(opt, name):
        params = model.init(jax.random.PRNGKey(seed))
        state = train_state_init(params, opt)
        step = make_split_train_step(model.loss_fn, opt, donate=False)
        rng = np.random.default_rng(seed)
        t_total = n_steps = 0
        curve = []
        for ep in range(epochs):
            order = rng.permutation(ntr)
            for s in range(0, ntr - 128, 128):
                idx = order[s:s + 128]
                b = {"x_slices": jnp.asarray(xs[:, idx]),
                     "labels": jnp.asarray(y[idx])}
                t0 = time.perf_counter()
                params, state, m = step(params, state, b, ep)
                jax.block_until_ready(m["loss"])
                t_total += time.perf_counter() - t0
                n_steps += 1
            val = {"x_slices": jnp.asarray(xs[:, ntr:]),
                   "labels": jnp.asarray(y[ntr:])}
            _, vm = model.loss_fn(params, val)
            curve.append(float(vm["accuracy"]))
        rows.append((name, 1e6 * t_total / max(n_steps, 1), curve[-1]))
        return curve

    # the paper's setup: per-segment SGD, owners 0.01 / scientist 0.1
    split_curve = train(multi_segment({
        "heads": sgd(CONFIG.split.owner_lr),
        "trunk": sgd(CONFIG.split.scientist_lr)}), "fig4_split_dualhead")
    # centralized baseline: same topology, one optimizer, one lr
    train(multi_segment({"heads": sgd(0.05), "trunk": sgd(0.05)}),
          "fig4_centralized_baseline")
    rows.append(("fig4_split_best_epoch", 0.0, max(split_curve)))
    return rows


if __name__ == "__main__":
    for r in run():
        print(",".join(str(x) for x in r))
