"""Benchmark harness — one module per paper table/figure plus the
TPU-roofline report.  Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
                                            [--check]

``--check`` re-measures the suites with committed ``BENCH_*.json``
baselines (transport, psi) into a temp directory and gates on the
per-metric tolerances in ``benchmarks.check`` — the perf-regression
analogue of the test suite.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="smaller fig4/transport runs (CI-sized)")
    ap.add_argument("--check", action="store_true",
                    help="compare fresh BENCH_*.json against the "
                         "committed baselines with tolerances")
    args = ap.parse_args()

    from benchmarks import (check, combine_ablation, cut_comm,
                            fig4_accuracy, kernels_bench, parties_bench,
                            privacy_bench, psi_scaling, recovery_bench,
                            serving_bench, split_overhead,
                            transport_bench)

    if args.check:
        # gated sections re-measured at the size the committed baseline
        # used (transport: full-size; psi: the CI-sized gate section —
        # its 1e6-ID trajectory is informational/skipped), written to a
        # scratch dir so baselines are never clobbered
        with tempfile.TemporaryDirectory() as tmp:
            print("name,us_per_call,derived")
            for row in transport_bench.run(
                    out=os.path.join(tmp, "BENCH_transport.json")):
                print(",".join(str(x) for x in row))
            for row in psi_scaling.run_check(
                    out=os.path.join(tmp, "BENCH_psi.json")):
                print(",".join(str(x) for x in row))
            for row in parties_bench.run_check(
                    out=os.path.join(tmp, "BENCH_parties.json")):
                print(",".join(str(x) for x in row))
            for row in serving_bench.run_check(
                    out=os.path.join(tmp, "BENCH_serving.json")):
                print(",".join(str(x) for x in row))
            for row in recovery_bench.run_check(
                    out=os.path.join(tmp, "BENCH_recovery.json")):
                print(",".join(str(x) for x in row))
            for row in privacy_bench.run_check(
                    out=os.path.join(tmp, "BENCH_privacy.json")):
                print(",".join(str(x) for x in row))
            if check.check(repo_root=".", fresh_dir=tmp):
                raise SystemExit(1)
        return

    suites = {
        "psi_scaling": (psi_scaling.run_fast if args.fast
                        else psi_scaling.run),
        "cut_comm": cut_comm.run,
        "kernels": kernels_bench.run,
        "split_overhead": split_overhead.run,
        "transport": (lambda: transport_bench.run(
                          n=1200, epochs=2, trials=1, sweep=False))
                      if args.fast else transport_bench.run,
        "parties": (parties_bench.run_fast if args.fast
                    else parties_bench.run),
        "serving": (serving_bench.run_fast if args.fast
                    else serving_bench.run),
        "recovery": recovery_bench.run,
        "privacy": privacy_bench.run,
        "combine_ablation": (lambda: combine_ablation.run(n=1500, epochs=4)
                             ) if args.fast else combine_ablation.run,
        "fig4_accuracy": (lambda: fig4_accuracy.run(n=2000, epochs=4))
                          if args.fast else fig4_accuracy.run,
    }

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn():
                print(",".join(str(x) for x in row))
            sys.stdout.flush()
        except Exception:                       # noqa: BLE001
            traceback.print_exc()
            failures += 1

    # roofline rows (from dry-run artifacts, if present)
    if not args.only or args.only == "roofline":
        try:
            from benchmarks import roofline
            recs = roofline.load(mesh="16x16")
            for rec in recs:
                t = roofline.terms(rec)
                print(f"roofline_{rec['arch']}_{rec['shape']},"
                      f"{t['bound_s']*1e6:.1f},{t['dominant']}")
        except Exception:                       # noqa: BLE001
            traceback.print_exc()
            failures += 1
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
