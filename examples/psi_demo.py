"""Private Set Intersection walkthrough — every message of both engine
variants (classic ECDH-PSI and the Bloom-compressed Angelou et al.
protocol PyVertical uses), with sizes, the 3-party resolution of paper
§3.1 through the streaming/parallel engine, and the same resolution
*over the transport layer* (``backend="queue"``) with per-party
**measured** wire bytes.

    PYTHONPATH=src python examples/psi_demo.py

(Also executed by ``make docs-check``.)
"""
import numpy as np

from repro.core.psi import GROUPS, PSIClient, PSIServer, psi_round
from repro.core.resolution import VerticalDataset, resolve

GROUP = "modp512"
NB = GROUPS[GROUP][2]                       # bytes per packed group element


def pairwise_demo(mode: str):
    print(f"=== pairwise DH-PSI, mode={mode!r}, message by message")
    hospital_a = ["alice", "bob", "carol", "dave"]
    insurer = ["bob", "dave", "erin", "frank", "grace"]
    client = PSIClient(insurer, GROUP, mode=mode)   # the data scientist
    server = PSIServer(hospital_a, fp_rate=1e-9, group=GROUP)

    wire = []
    inter, stats = psi_round(client, server, chunk_size=2,
                             on_message=lambda k, b: wire.append((k, b)))
    for kind, nbytes in wire:
        arrow = ("scientist -> owner" if kind == "psi_blind_chunk"
                 else "owner -> scientist")
        print(f"  {arrow}: {kind} ({nbytes} B)")
    print(f"  scientist learns: {sorted(inter)}")
    print(f"  owner learns: |scientist set| = {len(insurer)} — "
          "nothing else")
    down, raw = stats["server_response_bytes"], NB * len(hospital_a)
    if mode == "bloom":
        print(f"  server set crossed as a {stats['bloom_bytes']} B sharded"
              f" bloom (vs {raw} B raw) — paid for by one full-width"
              " unblind exponent per session")
    else:
        print(f"  every leg was a short exponentiation (no modular"
              f" inverse); server set crossed raw"
              f" ({stats['server_set_bytes']} B)")
    print(f"  total download: {down} B\n")
    return sorted(inter)


def resolution_demo():
    print("=== 3-party resolution (paper §3.1), chunked + parallel")
    rng = np.random.default_rng(0)
    sci = VerticalDataset([f"id{i}" for i in range(12)],
                          rng.integers(0, 10, 12))
    owners = {
        "hospital": VerticalDataset(
            [f"id{i}" for i in (0, 2, 3, 5, 7, 8, 11)],
            rng.normal(size=(7, 3))),
        "pharmacy": VerticalDataset(
            [f"id{i}" for i in (1, 2, 3, 5, 8, 9)],
            rng.normal(size=(6, 2))),
    }
    s_al, o_al, stats = resolve(sci, owners, group=GROUP,
                                chunk_size=4, parallelism=2)
    print("  pairwise: " + ", ".join(
        f"{r['owner']}={r['intersection_size']}"
        for r in stats["rounds"]))
    blind_cached = [r["blind_cached"] for r in stats["rounds"]]
    print(f"  scientist's blinded upload reused across owners: "
          f"{blind_cached}")
    print(f"  global intersection: {s_al.ids}")
    print("  owners never talked to each other; each sees only the "
          "final ID list")
    for name, ds in o_al.items():
        assert ds.ids == s_al.ids
    print("  alignment invariant verified: row n == same subject "
          "everywhere")


def wire_demo():
    print("\n=== resolve over the wire (backend='queue'), measured bytes")
    from repro.federation import VerticalSession
    from repro.federation.parties import DataOwner, DataScientist

    rng = np.random.default_rng(0)
    ids = [f"id{i}" for i in range(40)]
    sci = DataScientist(ids, rng.integers(0, 10, 40))
    owners = [DataOwner("hospital", ids[:30], rng.normal(size=(30, 3))),
              DataOwner("pharmacy", ids[10:], rng.normal(size=(30, 2)))]
    session = VerticalSession(sci, owners)
    stats = session.resolve(group=GROUP, backend="queue", chunk_size=8)
    print(f"  global intersection: {stats['global_intersection']} IDs")
    for name, wire in stats["per_party_wire"].items():
        print(f"  scientist <-> {name}: "
              f"uploaded {wire['sent_wire_bytes']} B, "
              f"downloaded {wire['recv_wire_bytes']} B "
              f"({wire['messages']} framed messages)")
    reuse = [m for m in session.transcript
             if m["kind"] == "psi_blind_reuse"]
    assert [m["to"] for m in reuse] == ["pharmacy"]
    print(f"  blinded upload computed once, reused for {reuse[0]['to']} "
          f"({reuse[0]['reused_upload_bytes']} B of modexp output)")
    r0, r1 = stats["rounds"]
    assert r0["upload_wire_bytes"] == r1["upload_wire_bytes"]
    print("  every leg crossed as a framed transport Message — byte "
          "counts above are measured from the serialized frames")


def main():
    assert pairwise_demo("noinv") == pairwise_demo("bloom")
    resolution_demo()
    wire_demo()


if __name__ == "__main__":
    main()
