"""Private Set Intersection walkthrough — every message of the Angelou et
al. protocol PyVertical uses, with sizes, plus the 3-party resolution of
paper §3.1.

    PYTHONPATH=src python examples/psi_demo.py
"""
import numpy as np

from repro.core.bloom import BloomFilter
from repro.core.psi import GROUPS, PSIClient, PSIServer
from repro.core.resolution import VerticalDataset, resolve

GROUP = "modp512"


def main():
    print("=== pairwise DH-PSI, message by message")
    hospital_a = ["alice", "bob", "carol", "dave"]
    insurer = ["bob", "dave", "erin", "frank", "grace"]
    client = PSIClient(insurer, GROUP)              # the data scientist
    server = PSIServer(hospital_a, fp_rate=1e-9, group=GROUP)

    blinded = client.blind()
    nb = GROUPS[GROUP][2]
    print(f"  scientist -> owner: {len(blinded)} blinded ids "
          f"({len(blinded) * nb} B)")
    double, bloom = server.respond(blinded)
    print(f"  owner -> scientist: {len(double)} double-blinded ids "
          f"({len(double) * nb} B) + bloom filter ({bloom.nbytes()} B, "
          f"vs {len(hospital_a) * nb} B uncompressed)")
    inter = client.intersect(double, bloom)
    print(f"  scientist learns: {sorted(inter)}")
    print(f"  owner learns: |scientist set| = {len(blinded)} — nothing else")

    print("\n=== 3-party resolution (paper §3.1)")
    rng = np.random.default_rng(0)
    sci = VerticalDataset([f"id{i}" for i in range(12)],
                          rng.integers(0, 10, 12))
    owners = {
        "hospital": VerticalDataset([f"id{i}" for i in (0, 2, 3, 5, 7, 8, 11)],
                                    rng.normal(size=(7, 3))),
        "pharmacy": VerticalDataset([f"id{i}" for i in (1, 2, 3, 5, 8, 9)],
                                    rng.normal(size=(6, 2))),
    }
    s_al, o_al, stats = resolve(sci, owners, group=GROUP)
    print(f"  pairwise: " + ", ".join(
        f"{r['owner']}={r['intersection_size']}" for r in stats["rounds"]))
    print(f"  global intersection: {s_al.ids}")
    print("  owners never talked to each other; each sees only the final "
          "ID list")
    for name, ds in o_al.items():
        assert ds.ids == s_al.ids
    print("  alignment invariant verified: row n == same subject everywhere")


if __name__ == "__main__":
    main()
