"""End-to-end driver (deliverable b): split-train a ~100M-parameter
llama-family model on vertically-partitioned token streams for a few
hundred steps, demonstrating the SplitNN machinery at LM scale: two
sequence-slice owners + a label-holding scientist, per-segment optimizers,
per-party checkpointing.

    PYTHONPATH=src python examples/train_vertical_llm.py \
        [--steps 300] [--batch 4] [--seq 256]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt
from repro.configs import get_config
from repro.core.splitnn import make_split_train_step, train_state_init
from repro.data import make_token_dataset, batches
from repro.models.model import SplitModel
from repro.optim import adam, chain, clip_by_global_norm, multi_segment


def build_100m():
    """~100M params in the llama3 family: 10L, d=768, 12H (kv=6), ff=3072,
    vocab 16384 -> embeds ~38M + blocks ~70M = ~108M."""
    return get_config("llama3.2-3b").replace(
        name="llama-100m", n_layers=10, d_model=768, n_heads=12,
        n_kv_heads=6, head_dim=64, d_ff=3072, vocab=16_384,
        tie_embeddings=False, zero_sharding=False,
    ).with_split(cut_layer=3)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/pyvertical_llm_ckpt")
    args = ap.parse_args(argv)

    cfg = build_100m()
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params, "
          f"{cfg.split.n_owners} owners, cut after "
          f"{model.n_head_units}/{cfg.n_superblocks} blocks")

    opt = multi_segment({
        "heads": chain(clip_by_global_norm(1.0), adam(args.lr)),
        "trunk": chain(clip_by_global_norm(1.0), adam(args.lr))})
    state = train_state_init(params, opt)
    step = make_split_train_step(model.loss_fn, opt)

    # generate over a 2048-token effective vocabulary (of the model's
    # 16384): the support + markov structure give a visible loss descent
    # within a couple hundred steps
    toks = make_token_dataset(256, args.seq, 2048, seed=0)
    it = batches({"t": toks}, args.batch, epochs=10_000)
    P = cfg.split.n_owners
    t0 = time.time()
    losses = []
    for i in range(args.steps):
        t = next(it)["t"]
        inp, lab = t[:, :-1], t[:, 1:]
        b = {"owner_tokens": jnp.asarray(
                inp.reshape(args.batch, P, args.seq // P).transpose(1, 0, 2)),
             "labels": jnp.asarray(lab)}
        params, state, m = step(params, state, b, i)
        losses.append(float(m["loss"]))
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                  f"({time.time()-t0:.0f}s)")
    d = ckpt.save_split(args.ckpt_dir, params, args.steps)
    print(f"per-party checkpoints -> {d}")
    print(f"loss: {losses[0]:.3f} -> {min(losses[-20:]):.3f} "
          f"(uniform = {np.log(cfg.vocab):.3f})")
    assert losses[-1] < losses[0], "no learning"
    return losses[-1]


if __name__ == "__main__":
    main()
