"""End-to-end driver (deliverable b): split-train a ~100M-parameter
llama-family model on vertically-partitioned token streams, as a thin
client of ``VerticalSession``: two sequence-slice owners + a
label-holding scientist, PSI resolution, per-segment adam, per-party
checkpointing.

    PYTHONPATH=src python examples/train_vertical_llm.py \
        [--steps 300] [--batch 4] [--seq 256]
"""
import argparse

import numpy as np

from repro.configs import get_config
from repro.data import make_token_dataset
from repro.federation import VerticalSession, sequence_parties


def build_100m():
    """~100M params in the llama3 family: 10L, d=768, 12H (kv=6), ff=3072,
    vocab 16384 -> embeds ~38M + blocks ~70M = ~108M."""
    return get_config("llama3.2-3b").replace(
        name="llama-100m", n_layers=10, d_model=768, n_heads=12,
        n_kv_heads=6, head_dim=64, d_ff=3072, vocab=16_384,
        tie_embeddings=False, zero_sharding=False,
    ).with_split(cut_layer=3)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/pyvertical_llm_ckpt")
    args = ap.parse_args(argv)

    cfg = build_100m()
    # a 2048-token effective vocabulary (of the model's 16384): the
    # support + markov structure give a visible loss descent quickly
    toks = make_token_dataset(256, args.seq, 2048, seed=0)
    session = VerticalSession(
        *sequence_parties(toks, cfg.split.n_owners))
    session.resolve(group="modp512")
    session.build(cfg)
    print(f"model: {cfg.name}, {session.adapter.model.n_head_units} head "
          f"blocks x {cfg.split.n_owners} owners; "
          f"{session.resolve_stats['global_intersection']} aligned docs")

    history = session.fit(steps=args.steps, batch_size=args.batch,
                          owner_lr=args.lr, scientist_lr=args.lr,
                          log_every=20)
    d = session.checkpoint(args.ckpt_dir, args.steps)
    print(f"per-party checkpoints -> {d}")

    losses = [r["loss"] for r in history["train"]]
    print(f"loss: {losses[0]:.3f} -> {min(losses[-20:]):.3f} "
          f"(uniform = {np.log(cfg.vocab):.3f})")
    assert losses[-1] < losses[0], "no learning"
    return losses[-1]


if __name__ == "__main__":
    main()
