"""Massively multi-headed VFL (the paper's §5.1 future-work axis):
accuracy and cut-layer traffic as the number of data owners grows
2 -> 4 -> 7 -> 14 (divisors of 784 features).

    PYTHONPATH=src python examples/multihead_scaling.py
    PYTHONPATH=src python examples/multihead_scaling.py --fast  # CI-sized

(``--fast`` is what ``make docs-check`` runs.)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SplitConfig
from repro.configs.pyvertical_mnist import MLPSplitConfig
from repro.core.splitnn import (MLPSplitNN, cut_layer_traffic,
                                make_split_train_step, train_state_init)
from repro.data import make_mnist_like
from repro.optim import multi_segment, sgd


def train_eval(n_owners, X, y, epochs=6, batch=128):
    cfg = MLPSplitConfig(split=SplitConfig(
        n_owners=n_owners, combine="concat", cut_dim=64,
        owner_lr=0.01, scientist_lr=0.1))
    model = MLPSplitNN(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = multi_segment({"heads": sgd(0.01), "trunk": sgd(0.1)})
    state = train_state_init(params, opt)
    step = make_split_train_step(model.loss_fn, opt, donate=False)
    n = len(y)
    ntr = int(n * 0.85)
    xs = np.stack(np.split(X, n_owners, axis=1))
    rng = np.random.default_rng(0)
    for ep in range(epochs):
        order = rng.permutation(ntr)
        for s in range(0, ntr - batch, batch):
            idx = order[s:s + batch]
            b = {"x_slices": jnp.asarray(xs[:, idx]),
                 "labels": jnp.asarray(y[idx])}
            params, state, _ = step(params, state, b, ep)
    val = {"x_slices": jnp.asarray(xs[:, ntr:]),
           "labels": jnp.asarray(y[ntr:])}
    _, vm = model.loss_fn(params, val)
    return float(vm["accuracy"])


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized run (fewer samples/epochs/owner "
                         "counts)")
    args = ap.parse_args(argv)
    n, epochs, batch = (600, 2, 64) if args.fast else (3000, 6, 128)
    owner_counts = (2, 4) if args.fast else (2, 4, 7, 14)
    X, y = make_mnist_like(n, seed=0)
    print(f"{'owners':>7} {'feat/owner':>11} {'val_acc':>8} "
          f"{'cut KiB/step':>13}")
    for p in owner_counts:
        acc = train_eval(p, X, y, epochs=epochs, batch=batch)
        t = cut_layer_traffic(p, batch, 1, 64, 4)
        print(f"{p:7d} {784 // p:11d} {acc:8.3f} "
              f"{t['total_per_step_bytes'] / 1024:13.1f}")
    print("\ncut traffic grows linearly with owners; accuracy degrades "
          "gracefully as each head sees narrower feature slices")


if __name__ == "__main__":
    main()
