"""Split-inference serving demo: batched requests flow through the
vertically-partitioned stack — owner heads prefill their private context
slices, the scientist's trunk decodes the continuation.  Multiple request
batches are served against one resident model (the serving loop a deployer
would run).

    PYTHONPATH=src python examples/serve_split.py [--arch llama3.2-3b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import make_token_dataset
from repro.models.model import SplitModel


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--n-batches", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    model = SplitModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    P = cfg.split.n_owners
    B, S = args.batch, args.ctx

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    print(f"serving {cfg.name} (reduced): {P} owner heads + trunk, "
          f"ctx {S}, {args.new} new tokens/request")
    all_toks = make_token_dataset(B * args.n_batches, S, cfg.vocab, 0)
    total_tok = 0
    t_start = time.time()
    for r in range(args.n_batches):
        toks = all_toks[r * B:(r + 1) * B, :S]
        owner_tokens = toks.reshape(B, P, S // P).transpose(1, 0, 2)
        caches = model.cache_init(B, S, n_new=args.new)
        t0 = time.time()
        logits, caches = prefill(
            params, {"owner_tokens": jnp.asarray(owner_tokens)}, caches)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out = [np.asarray(tok)]
        for t in range(args.new - 1):
            logits, caches = decode(params, caches, tok, S + t,
                                    S // P + t)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out.append(np.asarray(tok))
        dt = time.time() - t0
        total_tok += (args.new) * B
        gen = np.concatenate(out, axis=1)
        print(f"  batch {r}: {B} requests, {dt:.2f}s "
              f"({args.new * B / dt:.1f} tok/s)  "
              f"sample: {gen[0][:10].tolist()}")
    print(f"served {args.n_batches * B} requests, {total_tok} tokens "
          f"in {time.time()-t_start:.1f}s")


if __name__ == "__main__":
    main()
