"""Split-inference serving demo, as a thin client of ``VerticalSession``:
owners hold each request's private context slices, the scientist's trunk
decodes the continuation.  The session merges owner slices (owner-side),
queues every aligned request, and the engine serves them in waves against
one resident model.

By default the engine serves through a transport-backed boundary
(``--transport direct|queue|process``): every cut activation crosses a
real ``federation.transport`` channel, and the cut bytes reported at the
end are *measured* off that channel — not the analytic ``cut_traffic``
estimate.  ``--transport none`` restores the fused joint program.

``--continuous`` switches the engine from drain-by-waves to
slot-level continuous batching (freed slots are refilled immediately),
and ``--sessions N`` with N > 1 multiplexes N independent serving
sessions over ONE shared owner<->scientist channel via
``ServingService`` — each session's frames ride the same wire under a
session-scoped kind prefix, and repeat contexts across sessions hit the
shared cut cache.

    PYTHONPATH=src python examples/serve_split.py [--arch llama3.2-3b]
    PYTHONPATH=src python examples/serve_split.py --continuous \\
        --sessions 2 --transport process --latency-ms 2
"""
import argparse
import threading
import time

import numpy as np

from repro.configs import get_config
from repro.data import make_token_dataset
from repro.federation import VerticalSession, sequence_parties


def _serve_multiplexed(session, contexts, args):
    """N engine sessions sharing one channel through ServingService."""
    from repro.launch.engine import ServingService
    transport = "queue" if args.transport in ("none", "direct") \
        else args.transport
    svc = ServingService(session.adapter.model, session.params,
                         transport=transport,
                         latency_s=args.latency_ms * 1e-3,
                         scheduler="continuous" if args.continuous
                         else "wave")
    engines = [svc.session(batch_slots=args.batch,
                           ctx_len=contexts.shape[1], max_new=args.new)
               for _ in range(args.sessions)]
    shards = [contexts[i::args.sessions] for i in range(args.sessions)]
    results = [None] * args.sessions

    def drain(i):
        for row in shards[i]:
            engines[i].submit(row)
        results[i] = engines[i].run()

    t0 = time.time()
    threads = [threading.Thread(target=drain, args=(i,))
               for i in range(args.sessions)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    dt = time.time() - t0

    total_req = sum(len(r) for r in results)
    total_tok = sum(e.stats["tokens_generated"] for e in engines)
    print(f"multiplexed {args.sessions} sessions over one {transport} "
          f"channel: {total_req} requests, {total_tok} tokens "
          f"in {dt:.1f}s")
    for i, eng in enumerate(engines):
        st = eng.stats
        print(f"  session {i}: {st['requests']} requests, "
              f"{st['slot_refills']} slot refills, "
              f"{st['cut_payload_bytes']} cut payload B")
    ch = svc.channel_stats
    print(f"shared channel totals: {ch['wire_bytes']} wire B "
          f"across {ch['messages']} frames "
          f"(cache: {svc.cut_cache.hits} hits / "
          f"{svc.cut_cache.misses} misses)")
    svc.close()
    return {i: r for i, r in enumerate(results)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--n-batches", type=int, default=3)
    ap.add_argument("--transport", default="direct",
                    choices=["direct", "queue", "process", "none"],
                    help="channel backend for the cut boundary "
                         "(none = fused joint program, no measurement)")
    ap.add_argument("--latency-ms", type=float, default=0.0,
                    help="injected per-message channel latency")
    ap.add_argument("--continuous", action="store_true",
                    help="slot-level continuous batching instead of "
                         "drain-by-waves")
    ap.add_argument("--sessions", type=int, default=1,
                    help="N > 1 multiplexes N serving sessions over one "
                         "shared channel (ServingService)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    n_requests = args.batch * args.n_batches
    contexts = make_token_dataset(n_requests, args.ctx, cfg.vocab,
                                  0)[:, :args.ctx]
    session = VerticalSession(*sequence_parties(
        contexts, cfg.split.n_owners, with_labels=False))
    session.resolve(group="modp512")
    session.build(cfg)

    sched = "continuous" if args.continuous else "wave"
    print(f"serving {cfg.name} (reduced): {cfg.split.n_owners} owner heads "
          f"+ trunk, ctx {args.ctx}, {args.new} new tokens/request "
          f"({sched} scheduler)")
    if args.sessions > 1:
        return _serve_multiplexed(session, np.asarray(contexts), args)
    transport = None if args.transport == "none" else args.transport
    t0 = time.time()
    results, engine = session.serve_dataset(
        max_new=args.new, batch_slots=args.batch, transport=transport,
        latency_s=args.latency_ms * 1e-3, scheduler=sched)
    dt = time.time() - t0
    st = engine.stats
    for rid in sorted(results)[:3]:
        print(f"  request {rid}: sample {results[rid].generated[:10]}")
    batches = (f"{st['waves']} waves" if sched == "wave"
               else f"{st['ticks']} ticks, {st['slot_refills']} refills")
    print(f"served {st['requests']} requests in {batches}, "
          f"{st['tokens_generated']} tokens in {dt:.1f}s "
          f"({st['tokens_generated'] / dt:.1f} tok/s)")
    if transport is not None:
        print(f"measured cut traffic: {st['cut_payload_bytes']} payload B "
              f"({st['cut_wire_bytes']} on the wire) across "
              f"{st['cut_messages']} messages — the only owner->scientist "
              f"tensors (raw context slices: ZERO)")
    return results


if __name__ == "__main__":
    main()
