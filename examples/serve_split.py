"""Split-inference serving demo, as a thin client of ``VerticalSession``:
owners hold each request's private context slices, the scientist's trunk
decodes the continuation.  The session merges owner slices (owner-side),
queues every aligned request, and the engine serves them in waves against
one resident model.

By default the engine serves through a transport-backed boundary
(``--transport direct|queue``): every cut activation crosses a real
``federation.transport`` channel, and the cut bytes reported at the end
are *measured* off that channel — not the analytic ``cut_traffic``
estimate.  ``--transport none`` restores the fused joint program.

    PYTHONPATH=src python examples/serve_split.py [--arch llama3.2-3b]
"""
import argparse
import time

from repro.configs import get_config
from repro.data import make_token_dataset
from repro.federation import VerticalSession, sequence_parties


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--n-batches", type=int, default=3)
    ap.add_argument("--transport", default="direct",
                    choices=["direct", "queue", "none"],
                    help="channel backend for the cut boundary "
                         "(none = fused joint program, no measurement)")
    ap.add_argument("--latency-ms", type=float, default=0.0,
                    help="injected per-message channel latency")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    n_requests = args.batch * args.n_batches
    contexts = make_token_dataset(n_requests, args.ctx, cfg.vocab,
                                  0)[:, :args.ctx]
    session = VerticalSession(*sequence_parties(
        contexts, cfg.split.n_owners, with_labels=False))
    session.resolve(group="modp512")
    session.build(cfg)

    print(f"serving {cfg.name} (reduced): {cfg.split.n_owners} owner heads "
          f"+ trunk, ctx {args.ctx}, {args.new} new tokens/request")
    transport = None if args.transport == "none" else args.transport
    t0 = time.time()
    results, engine = session.serve_dataset(
        max_new=args.new, batch_slots=args.batch, transport=transport,
        latency_s=args.latency_ms * 1e-3)
    dt = time.time() - t0
    st = engine.stats
    for rid in sorted(results)[:3]:
        print(f"  request {rid}: sample {results[rid].generated[:10]}")
    print(f"served {st['requests']} requests in {st['waves']} waves, "
          f"{st['tokens_generated']} tokens in {dt:.1f}s "
          f"({st['tokens_generated'] / dt:.1f} tok/s)")
    if transport is not None:
        print(f"measured cut traffic: {st['cut_payload_bytes']} payload B "
              f"({st['cut_wire_bytes']} on the wire) across "
              f"{st['cut_messages']} messages — the only owner->scientist "
              f"tensors (raw context slices: ZERO)")
    return results


if __name__ == "__main__":
    main()
