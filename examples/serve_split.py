"""Split-inference serving demo, as a thin client of ``VerticalSession``:
owners hold each request's private context slices, the scientist's trunk
decodes the continuation.  The session merges owner slices (owner-side),
queues every aligned request, and the engine serves them in waves against
one resident model.

    PYTHONPATH=src python examples/serve_split.py [--arch llama3.2-3b]
"""
import argparse
import time

from repro.configs import get_config
from repro.data import make_token_dataset
from repro.federation import VerticalSession, sequence_parties


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--ctx", type=int, default=128)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--n-batches", type=int, default=3)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    n_requests = args.batch * args.n_batches
    contexts = make_token_dataset(n_requests, args.ctx, cfg.vocab,
                                  0)[:, :args.ctx]
    session = VerticalSession(*sequence_parties(
        contexts, cfg.split.n_owners, with_labels=False))
    session.resolve(group="modp512")
    session.build(cfg)

    print(f"serving {cfg.name} (reduced): {cfg.split.n_owners} owner heads "
          f"+ trunk, ctx {args.ctx}, {args.new} new tokens/request")
    t0 = time.time()
    results, engine = session.serve_dataset(max_new=args.new,
                                            batch_slots=args.batch)
    dt = time.time() - t0
    st = engine.stats
    for rid in sorted(results)[:3]:
        print(f"  request {rid}: sample {results[rid].generated[:10]}")
    print(f"served {st['requests']} requests in {st['waves']} waves, "
          f"{st['tokens_generated']} tokens in {dt:.1f}s "
          f"({st['tokens_generated'] / dt:.1f} tok/s)")
    return results


if __name__ == "__main__":
    main()
