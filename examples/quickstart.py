"""PyVertical quickstart — the paper's Figure 2 pipeline, end to end.

Two data owners each hold one half of every image; the data scientist
holds the labels.  ``VerticalSession`` runs the whole protocol: DH-PSI
entity resolution, ID alignment, and dual-headed SplitNN training with
per-party learning rates (Appendix B).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.configs.pyvertical_mnist import CONFIG
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties


def main():
    sci, owners = make_vertical_mnist_parties(2000, seed=0, keep_frac=0.9)
    session = VerticalSession(*feature_parties(sci, owners))

    stats = session.resolve(group="modp512")
    print(f"PSI: {stats['global_intersection']} shared subjects "
          + " ".join(f"[{r['owner']}: {r['intersection_size']} pairwise, "
                     f"{r['server_response_bytes'] / 1024:.1f} KiB]"
                     for r in stats["rounds"]))

    session.build(CONFIG)
    history = session.fit(epochs=10, batch_size=128, eval_frac=0.15)

    traffic = session.cut_traffic(batch_size=128)
    print(f"final val_acc={history['final']['val_accuracy']:.3f}; "
          f"per step each owner sent {traffic['per_owner_forward_bytes']} B "
          f"of cut activations (raw pixels: ZERO)")
    return history["final"]["val_accuracy"]


if __name__ == "__main__":
    main()
