"""PyVertical quickstart — the paper's Figure 2 pipeline, end to end.

Two data owners each hold one half of every image; the data scientist
holds the labels.  The parties PSI-resolve their shared subjects, align
by ID, and train the dual-headed SplitNN of Appendix B.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.pyvertical_mnist import CONFIG
from repro.core import MLPSplitNN, make_split_train_step, resolve
from repro.core.splitnn import cut_layer_traffic, train_state_init
from repro.data import make_vertical_mnist_parties
from repro.optim import multi_segment, sgd


def main():
    print("=== 1. vertical data: 2 owners x half-images + scientist labels")
    sci, owners = make_vertical_mnist_parties(2000, seed=0, keep_frac=0.9)
    for name, ds in owners.items():
        print(f"  {name}: {len(ds.ids)} subjects, {ds.data.shape[1]} features")

    print("=== 2. PSI resolution (DH-PSI + Bloom compression)")
    t0 = time.time()
    s_al, o_al, stats = resolve(sci, owners, group="modp512")
    print(f"  global intersection: {stats['global_intersection']} subjects "
          f"({time.time()-t0:.1f}s)")
    for r in stats["rounds"]:
        print(f"  {r['owner']}: pairwise {r['intersection_size']}, "
              f"server response {r['server_response_bytes']/1024:.1f} KiB")

    print("=== 3. dual-headed SplitNN training (Appendix B hyperparams)")
    model = MLPSplitNN(CONFIG)
    params = model.init(jax.random.PRNGKey(0))
    opt = multi_segment({"heads": sgd(CONFIG.split.owner_lr),
                         "trunk": sgd(CONFIG.split.scientist_lr)})
    state = train_state_init(params, opt)
    step = make_split_train_step(model.loss_fn, opt, donate=False)

    xs = np.stack([o_al["owner0"].data, o_al["owner1"].data])
    ys = s_al.data.astype(np.int32)
    n = len(ys)
    ntr = int(n * 0.85)
    rng = np.random.default_rng(0)
    for ep in range(10):
        order = rng.permutation(ntr)
        for s in range(0, ntr - 128, 128):
            idx = order[s:s + 128]
            b = {"x_slices": jnp.asarray(xs[:, idx]),
                 "labels": jnp.asarray(ys[idx])}
            params, state, m = step(params, state, b, ep)
        val = {"x_slices": jnp.asarray(xs[:, ntr:]),
               "labels": jnp.asarray(ys[ntr:])}
        _, vm = model.loss_fn(params, val)
        print(f"  epoch {ep}: train_acc={float(m['accuracy']):.3f} "
              f"val_acc={float(vm['accuracy']):.3f}")

    t = cut_layer_traffic(2, 128, 1, 64, 4)
    print("=== 4. what crossed party boundaries per step:")
    print(f"  {t['per_owner_forward_bytes']} B fwd + "
          f"{t['per_owner_backward_bytes']} B bwd per owner "
          f"(raw pixels: ZERO)")


if __name__ == "__main__":
    main()
