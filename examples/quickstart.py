"""PyVertical quickstart — the paper's Figure 2 pipeline, end to end.

Two data owners each hold one half of every image; the data scientist
holds the labels.  ``VerticalSession`` runs the whole protocol: DH-PSI
entity resolution, ID alignment, and dual-headed SplitNN training with
per-party learning rates (Appendix B).

``--mode split`` runs *true* split execution: each owner's head segment
computes behind a ``federation.transport`` channel (optionally
latency-injected via ``--latency-ms``), only cut activations/gradients
cross the boundary, and the traffic report is measured wire bytes.
``--backend process`` puts every owner in its own spawned worker
process over a real OS pipe (``federation/runtime.py``) — same frames,
same bytes, genuinely parallel head compute; ``--owners N`` scales the
party count (equal feature widths).  ``--compression fp16|int8``
quantizes the cut payloads on the way out.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --mode split \
        --latency-ms 1 --compression int8
    PYTHONPATH=src python examples/quickstart.py --mode split \
        --backend process --owners 4 --epochs 2
"""
import argparse
import dataclasses

from repro.configs.base import SplitConfig
from repro.configs.pyvertical_mnist import CONFIG
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties


def _config(owners: int):
    if owners == CONFIG.split.n_owners:
        return CONFIG
    return dataclasses.replace(
        CONFIG, split=SplitConfig(
            n_owners=owners, cut_layer=1, combine="concat", cut_dim=64,
            owner_lr=0.01, scientist_lr=0.1))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="joint", choices=["joint", "split"])
    ap.add_argument("--schedule", default="pipelined",
                    choices=["pipelined", "sequential"])
    ap.add_argument("--backend", default="queue",
                    choices=["queue", "direct", "process"],
                    help="split-mode party boundary: thread-backed "
                         "queue, in-process direct, or one spawned "
                         "worker process per owner")
    ap.add_argument("--owners", type=int, default=2,
                    help="number of data owners (feature dim must "
                         "divide evenly)")
    ap.add_argument("--compression", default="none",
                    choices=["none", "fp16", "int8"])
    ap.add_argument("--latency-ms", type=float, default=0.0,
                    help="injected channel latency (split mode)")
    ap.add_argument("--microbatches", type=int, default=1,
                    help="GPipe chunks in flight per channel "
                         "(split pipelined mode)")
    ap.add_argument("--epochs", type=int, default=10)
    args = ap.parse_args(argv)

    sci, owners = make_vertical_mnist_parties(
        2000, n_owners=args.owners, seed=0, keep_frac=0.9)
    session = VerticalSession(*feature_parties(sci, owners))

    stats = session.resolve(group="modp512")
    print(f"PSI: {stats['global_intersection']} shared subjects "
          + " ".join(f"[{r['owner']}: {r['intersection_size']} pairwise, "
                     f"{r['server_response_bytes'] / 1024:.1f} KiB]"
                     for r in stats["rounds"]))

    session.build(_config(args.owners))
    history = session.fit(epochs=args.epochs, batch_size=128,
                          eval_frac=0.15, mode=args.mode,
                          schedule=args.schedule,
                          compression=args.compression,
                          microbatches=args.microbatches,
                          backend=args.backend,
                          latency_s=args.latency_ms * 1e-3)

    if args.mode == "split":
        ts = session.transport_stats
        print(f"final val_acc={history['final']['val_accuracy']:.3f}; "
              f"{ts['schedule']} schedule over {ts['backend']} transport "
              f"({ts['compression']} codec): measured "
              f"{ts['cut_payload_bytes_per_step']} B/step of cut "
              f"activations, {ts['step_ms']:.1f} ms/step, "
              f"M={ts['microbatches']} in flight "
              f"(raw pixels: ZERO)")
    else:
        traffic = session.cut_traffic(batch_size=128)
        print(f"final val_acc={history['final']['val_accuracy']:.3f}; "
              f"per step each owner sent "
              f"{traffic['per_owner_forward_bytes']} B "
              f"of cut activations (raw pixels: ZERO)")
    return history["final"]["val_accuracy"]


if __name__ == "__main__":
    main()
