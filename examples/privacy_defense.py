"""Beyond-paper: the §5.1 privacy-defence sweep (Titcombe et al. 2021).

Trains the paper's SplitNN with increasing Gaussian noise on the cut
activations and reports the accuracy/leakage trade-off, where leakage is
the distance correlation between an owner's raw inputs and the cut
representation the scientist sees.

    PYTHONPATH=src python examples/privacy_defense.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.pyvertical_mnist import CONFIG
from repro.core.privacy import distance_correlation
from repro.core.splitnn import (MLPSplitNN, make_split_train_step,
                                train_state_init)
from repro.data import make_mnist_like
from repro.optim import multi_segment, sgd


def main():
    X, y = make_mnist_like(2500, seed=0)
    xs = np.stack(np.split(X, 2, axis=1))
    n = len(y)
    ntr = int(n * 0.85)
    print(f"{'noise_std':>10} {'val_acc':>8} {'leak_dcor':>10}")
    for std in (0.0, 0.25, 0.5, 1.0, 2.0):
        cfg = dataclasses.replace(
            CONFIG, split=dataclasses.replace(CONFIG.split,
                                              cut_noise_std=std))
        model = MLPSplitNN(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = multi_segment({"heads": sgd(0.01), "trunk": sgd(0.1)})
        state = train_state_init(params, opt)

        def loss_fn(p, b, rng=None):
            return model.loss_fn(p, b, rng)

        step = make_split_train_step(loss_fn, opt, donate=False)
        rng = np.random.default_rng(0)
        key = jax.random.PRNGKey(1)
        for ep in range(6):
            order = rng.permutation(ntr)
            for s in range(0, ntr - 128, 128):
                idx = order[s:s + 128]
                key, k = jax.random.split(key)
                b = {"x_slices": jnp.asarray(xs[:, idx]),
                     "labels": jnp.asarray(y[idx])}
                params, state, _ = step(params, state, b, ep, k)
        val = {"x_slices": jnp.asarray(xs[:, ntr:]),
               "labels": jnp.asarray(y[ntr:])}
        _, vm = model.loss_fn(params, val)
        # leakage: dcor(raw half-images, noisy cut) for owner 0
        cut = model.heads_forward(params["heads"],
                                  jnp.asarray(xs[:, ntr:ntr + 256]))
        key, k = jax.random.split(key)
        noisy = cut[0] + std * jax.random.normal(k, cut[0].shape)
        leak = float(distance_correlation(
            jnp.asarray(xs[0, ntr:ntr + 256]), noisy))
        print(f"{std:10.2f} {float(vm['accuracy']):8.3f} {leak:10.3f}")
    print("\nmore cut-layer noise -> lower leakage, modest accuracy cost — "
          "the defence the paper lists as future work")


if __name__ == "__main__":
    main()
