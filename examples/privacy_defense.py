"""Beyond-paper: the §5.1 privacy-defence sweep (Titcombe et al. 2021),
run as REAL federated training on the wire.

Each row trains the paper's SplitNN through a ``VerticalSession`` split
fit on the queue backend with a different cut-layer defence, taps every
serialized frame, and reports the trade-off:

  * ``val_acc``   — held-out accuracy of the defended model;
  * ``leak_dcor`` — distance correlation between owner0's raw rows and
    the frames actually observed on the wire (the NoPeek leakage
    metric, measured on captured traffic — not on in-process tensors);
  * ``cut_MB``    — measured cut-payload bytes shipped by the owners
    (from the session's transport accounting, never estimated).

The masked_sum row is the secure-aggregation endpoint of the sweep: the
wire carries uniform ring elements (leakage at the independence floor)
at exactly zero extra forward bytes.

    PYTHONPATH=src python examples/privacy_defense.py [--fast]
"""
import argparse
import dataclasses

import numpy as np

from repro.configs.pyvertical_mnist import CONFIG
from repro.core.privacy import distance_correlation
from repro.data import make_vertical_mnist_parties
from repro.federation import VerticalSession, feature_parties, transport
from repro.federation.transport import _unpack


def run_one(*, n, steps, batch, cut_noise_std=0.0, aggregation=None):
    """One defended split fit with every frame tapped.  Returns
    (val_acc, wire leak dcor for owner0, measured cut bytes)."""
    captured = []
    orig = transport.channel_pair

    def tapped(a, b, **kw):
        kw["tap"] = lambda msg, blob: captured.append(
            (msg.sender, msg.kind, msg.seq, blob))
        return orig(a, b, **kw)

    transport.channel_pair = tapped
    try:
        sci, owners = make_vertical_mnist_parties(n, seed=0,
                                                  keep_frac=0.9)
        s = VerticalSession(*feature_parties(sci, owners))
        s.resolve(group="modp512")
        s.build(dataclasses.replace(CONFIG, split=dataclasses.replace(
            CONFIG.split, combine="sum", cut_noise_std=cut_noise_std)))
        s.fit(steps=steps, batch_size=batch, eval_frac=0.15,
              verbose=False, mode="split", backend="queue",
              aggregation=aggregation)
    finally:
        transport.channel_pair = orig

    acc = s.evaluate()["accuracy"]
    owner0 = s.owners[0]
    raw = np.asarray(owner0._features, np.float32)
    batches, leaks = {}, []
    for sender, kind, seq, blob in captured:
        if kind == "head_fwd":
            batches[seq] = np.asarray(_unpack(blob)["idx"], np.int32)
    for sender, kind, seq, blob in captured:
        if sender == owner0.name and kind == "cut_activations":
            payload = _unpack(blob)
            z = (payload["mq"].view(np.int32).astype(np.float32)
                 if "mq" in payload
                 else np.asarray(payload["x"], np.float32))
            leaks.append(float(distance_correlation(
                raw[batches[seq]], z)))
    cut_bytes = sum(s.transport_stats["per_owner"][o.name]
                    ["cut_payload_bytes"] for o in s.owners)
    return float(acc), float(np.mean(leaks)), cut_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized sweep (docs-check)")
    args = ap.parse_args()
    n, steps, batch = ((600, 6, 64) if args.fast else (2500, 60, 128))

    rows = [("none", dict())]
    rows += [(f"noise={std}", dict(cut_noise_std=std))
             for std in (0.5, 2.0)]
    rows += [("masked_sum", dict(aggregation="masked_sum"))]

    print(f"{'defence':>12} {'val_acc':>8} {'leak_dcor':>10} "
          f"{'cut_MB':>8}")
    base_leak = base_bytes = None
    results = {}
    for name, kw in rows:
        acc, leak, cut_bytes = run_one(n=n, steps=steps, batch=batch,
                                       **kw)
        results[name] = (acc, leak, cut_bytes)
        if name == "none":
            base_leak, base_bytes = leak, cut_bytes
        print(f"{name:>12} {acc:8.3f} {leak:10.3f} "
              f"{cut_bytes / 1e6:8.3f}")

    assert results["masked_sum"][1] < base_leak, \
        "masked frames must leak less than plain cuts"
    assert results["masked_sum"][2] == base_bytes, \
        "ring coding must cost zero extra forward bytes"
    print("\nmore cut-layer defence -> lower wire leakage at modest "
          "accuracy cost; masked_sum reaches the independence floor "
          "for free (measured bytes equal)")


if __name__ == "__main__":
    main()
